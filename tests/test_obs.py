"""repro.obs — end-to-end tracing and metrics.

Four contracts:

* **tracer mechanics** — nesting via the per-thread parent stack, ring
  capacity + drop accounting, and the disabled tracer being a true
  no-op (shared null span, nothing allocated or recorded).
* **span tree shape** — a search through ``SearchServer`` produces the
  documented taxonomy: pool verb events nest under ``compute.fetch``
  which nests under ``compute.round`` / ``compute.search`` under the
  serve window spans.
* **wire propagation** — against a loopback ``PoolServer`` the client
  negotiates FLAG_TRACE at PING, stamps verb frames with trace context,
  and harvests server-side service-time spans whose durations are
  covered by the matching client-side ``net.*`` span; a server that
  never acks the flag (old server) is simply never sent trace bytes.
* **observability is free** — with tracing off OR on, results and the
  NetLedger are bit-identical across every transport x quant combo;
  only the tracer's own buffer grows.

Plus exporter round-trips (Chrome trace JSON, Prometheus text, the
report CLI) and the serving benchmark's counted-pass determinism that
``benchmarks/perf_gate.py`` relies on.
"""
from __future__ import annotations

import json
import sys
import threading
from pathlib import Path

import numpy as np
import pytest

from repro.core import DHNSWEngine, EngineConfig
from repro.core.cost_model import RDMA_100G
from repro.net.server import PoolServer
from repro.obs import report
from repro.obs.hist import (HIST_BOUNDS, LatencyHistogram, StragglerDetector,
                            VerbShardHist)
from repro.obs.metrics import render_pool_server, render_prometheus
from repro.obs.slo import SLO, SLOTracker, parse_slo
from repro.obs.trace import TRACER, Tracer, chrome_trace, load_trace
from repro.pool.protocol import PoolUnavailableError
from repro.rdma.inject import InjectedFault, WRInjector
from repro.serve.batcher import BatchPolicy
from repro.serve.server import SearchServer

CFG = dict(mode="full", search_mode="scan", n_rep=12, b=3, ef=32,
           cache_frac=0.25, seed=3)


@pytest.fixture(autouse=True)
def _tracer_guard():
    """Every test leaves the process-global tracer disabled."""
    yield
    TRACER.disable()


@pytest.fixture()
def pds(sift_small):
    return sift_small.data[:1200], sift_small.queries[:16]


def _by_id(spans):
    return {s["id"]: s for s in spans}


def _ancestors(span, idx):
    out = []
    while span["parent"]:
        span = idx[span["parent"]]
        out.append(span["name"])
    return out


# ------------------------------------------------------------ mechanics


def test_disabled_tracer_is_noop():
    tr = Tracer()
    s1 = tr.span("a")
    s2 = tr.span("b", tier="x", big=1)
    assert s1 is s2                      # shared null object, no allocs
    with s1 as s:
        assert s.span_id == 0
    tr.event("e")
    tr.add("t", "x", 0.0, 1.0)
    assert tr.add_span("u", "x", 0.0, 1.0) == 0
    assert tr.snapshot() == []


def test_nesting_and_threads():
    tr = Tracer()
    tr.configure(trace_id=9)
    with tr.span("outer", tier="t") as outer:
        with tr.span("inner", tier="t"):
            tr.event("leaf", tier="t")
        assert tr._current_id() == outer.span_id

        def other():
            with tr.span("sibling", tier="t"):
                pass

        th = threading.Thread(target=other)
        th.start()
        th.join()
    spans = {s["name"]: s for s in tr.snapshot()}
    assert spans["leaf"]["parent"] == spans["inner"]["id"]
    assert spans["inner"]["parent"] == spans["outer"]["id"]
    assert spans["outer"]["parent"] == 0
    # a thread with no open span must not inherit another thread's stack
    assert spans["sibling"]["parent"] == 0
    assert spans["sibling"]["tid"] != spans["outer"]["tid"]
    assert all(s["trace"] == 9 for s in spans.values())


def test_capacity_and_drop_counter():
    tr = Tracer(capacity=4)
    tr.configure(trace_id=1)
    for i in range(7):
        tr.event(f"e{i}")
    assert len(tr.snapshot()) == 4
    assert tr.dropped == 3
    assert [s["name"] for s in tr.snapshot()] == ["e3", "e4", "e5", "e6"]


def test_phase_tagging():
    tr = Tracer()
    tr.configure(trace_id=1)
    tr.set_phase("warm")
    tr.event("a")
    tr.set_phase(None)
    tr.event("b")
    a, b = tr.snapshot()
    assert a["attrs"]["phase"] == "warm" and "phase" not in b["attrs"]


# ------------------------------------------------------------ tree shape


def test_span_tree_through_search_server(pds):
    data, queries = pds
    TRACER.configure(trace_id=5)
    eng = DHNSWEngine(EngineConfig(**CFG)).build(data)
    with SearchServer(eng, BatchPolicy(max_batch=8, max_wait_s=1e-3)) as srv:
        srv.search(queries[:2], k=5)
    spans = TRACER.snapshot()
    idx = _by_id(spans)
    verbs = [s for s in spans if s["tier"] == "pool"
             and s["name"] == "pool.read_spans"]
    assert verbs, [s["name"] for s in spans]
    chain = _ancestors(verbs[-1], idx)
    # pool verb -> fetch -> round -> client search -> engine facade ->
    # serve dispatch -> serve window
    for name in ("compute.fetch", "compute.round", "compute.search",
                 "serve.dispatch", "serve.window"):
        assert name in chain, (name, chain)
    queue = [s for s in spans if s["name"] == "serve.queue"]
    assert queue and all(s["tier"] == "serve" for s in queue)


# ------------------------------------------------------------ wire


def test_trace_flag_roundtrip_loopback(pds):
    data, queries = pds
    srv = PoolServer()
    srv.start()
    try:
        TRACER.configure(trace_id=21)
        eng = DHNSWEngine(EngineConfig(**CFG, pool="remote",
                                       endpoints=(srv.endpoint,))
                          ).build(data)
        eng.search(queries[:4], k=5)
        pool = eng.pool
        assert pool._server_trace is True     # PING capability ack
        n = pool.harvest_trace()
        assert n > 0
        spans = TRACER.snapshot()
        idx = _by_id(spans)
        server_spans = [s for s in spans if s["tier"] == "server"]
        assert len(server_spans) == n
        for s in server_spans:
            parent = idx[s["parent"]]
            assert parent["tier"] == "net"
            assert parent["name"] == "net." + s["name"][len("server."):]
            # client-side verb span covers the server service time
            assert parent["dur"] >= s["dur"] - 1e-9
            # re-based inside the parent on the client clock
            assert parent["t0"] - 1e-9 <= s["t0"]
            assert s["t0"] + s["dur"] <= parent["t0"] + parent["dur"] + 1e-9
            assert s["attrs"]["clock"] == "server"
        # drained: a second harvest only sees the previous harvest's own
        # traced STATS drain request, never a verb span twice
        n_before = len([s for s in TRACER.snapshot()
                        if s["tier"] == "server"])
        pool.harvest_trace()
        fresh = [s for s in TRACER.snapshot()
                 if s["tier"] == "server"][n_before:]
        assert all(s["name"] == "server.stats" for s in fresh)
        pool.close()
    finally:
        TRACER.disable()
        srv.stop()


def test_old_server_never_sent_trace_bytes(pds):
    data, queries = pds
    srv = PoolServer()
    srv.start()
    try:
        eng = DHNSWEngine(EngineConfig(**CFG, pool="remote",
                                       endpoints=(srv.endpoint,))
                          ).build(data)
        d0, g0, s0 = eng.search(queries[:4], k=5)
        eng.pool.close()

        TRACER.configure(trace_id=33)
        eng = DHNSWEngine(EngineConfig(**CFG, pool="remote",
                                       endpoints=(srv.endpoint,))
                          ).build(data)
        # simulate an old server: the PING ack never arrived, so the
        # client must not prefix trace context onto any frame
        eng.pool._server_trace = False
        d1, g1, s1 = eng.search(queries[:4], k=5)
        assert np.array_equal(np.asarray(d0), np.asarray(d1))
        assert np.array_equal(np.asarray(g0), np.asarray(g1))
        assert s0["net"]["bytes"] == s1["net"]["bytes"]
        assert eng.pool.harvest_trace() == 0
        assert not any(s["tier"] == "server" for s in TRACER.snapshot())
        eng.pool.close()
    finally:
        TRACER.disable()
        srv.stop()


# ------------------------------------------------------------ free-ness


def _run_combo(data, queries, pool_kind, quant, endpoints=None):
    kw = dict(CFG, pool=pool_kind, quant=quant)
    if pool_kind == "sharded":
        kw["n_shards"] = 2
    if pool_kind == "remote":
        kw["endpoints"] = endpoints
    eng = DHNSWEngine(EngineConfig(**kw)).build(data)
    d, g, st = eng.search(queries, k=5)
    out = (np.asarray(d).copy(), np.asarray(g).copy(), dict(st["net"]))
    if pool_kind == "remote":
        eng.pool.close()
    return out


@pytest.mark.parametrize("pool_kind", ["local", "sim_rdma", "sharded",
                                       "remote"])
@pytest.mark.parametrize("quant", ["none", "int8"])
def test_tracing_off_vs_on_bit_identical(pds, pool_kind, quant):
    data, queries = pds
    srv = None
    endpoints = None
    if pool_kind == "remote":
        srv = PoolServer()
        srv.start()
        endpoints = (srv.endpoint,)
    try:
        TRACER.disable()
        d0, g0, net0 = _run_combo(data, queries[:6], pool_kind, quant,
                                  endpoints)
        TRACER.configure(trace_id=11)
        d1, g1, net1 = _run_combo(data, queries[:6], pool_kind, quant,
                                  endpoints)
        assert len(TRACER.snapshot()) > 0
        assert np.array_equal(d0, d1)
        assert np.array_equal(g0, g1)
        assert net0 == net1      # ledger parity: tracing charges nothing
    finally:
        TRACER.disable()
        if srv is not None:
            srv.stop()


# ------------------------------------------------------------ exporters


def test_chrome_trace_round_trip(tmp_path):
    tr = Tracer()
    tr.configure(trace_id=3)
    with tr.span("a", tier="serve", rows=2):
        tr.event("b", tier="pool", bytes=4096.0)
    path = tmp_path / "t.json"
    assert tr.save(path) == 2
    spans = load_trace(path)
    orig = tr.snapshot()
    assert [s["name"] for s in spans] == [s["name"] for s in orig]
    assert spans[1]["attrs"]["rows"] == 2
    assert spans[0]["parent"] == spans[1]["id"]
    for a, b in zip(spans, orig):
        assert a["trace"] == b["trace"] == 3
        assert abs(a["dur"] - b["dur"]) < 1e-6
    blob = chrome_trace(orig)
    assert all(ev["ph"] == "X" for ev in blob["traceEvents"])


def test_report_names_dominant_stage(tmp_path, capsys):
    tr = Tracer()
    tr.configure(trace_id=7)
    for phase, slow in (("serial", 0.010), ("batched", 0.002)):
        tr.set_phase(phase)
        with tr.span(report.REQUEST_SPAN, tier="bench"):
            tr.add("stage.slow", "compute", 0.0, slow)
            tr.add("stage.fast", "compute", 0.0, 0.001)
    path = tmp_path / "t.json"
    tr.save(path)
    assert report.main([str(path)]) == 0
    out = capsys.readouterr().out
    assert "dominant stage" in out
    # the gap table must name the stage whose per-request self time
    # moved, not merely the biggest absolute stage
    assert "batched-vs-serial gap" in out
    assert "stage.slow" in out


def test_prometheus_renderers(pds):
    data, queries = pds
    TRACER.configure(trace_id=13)
    eng = DHNSWEngine(EngineConfig(**CFG)).build(data)
    with SearchServer(eng, BatchPolicy(max_batch=8, max_wait_s=1e-3)) as srv:
        srv.search(queries[:2], k=5)
        txt = srv.metrics_text()
    assert "# TYPE repro_serve_requests_total counter" in txt
    assert "repro_serve_requests_total 1" in txt
    assert "repro_span_seconds_bucket" in txt
    assert 'repro_pool_verbs_total{verb="read_spans"}' in txt
    assert "repro_cache_hit_ratio" in txt
    # every exposition line parses: "name{...} value" with float value
    for line in txt.strip().splitlines():
        if line.startswith("#"):
            continue
        float(line.rsplit(" ", 1)[1])
    pool_txt = render_pool_server({"verbs": {"read_rows": 3},
                                   "service_s": {"read_rows": 0.5},
                                   "payload_rx": 10, "payload_tx": 20,
                                   "uptime_s": 1.5})
    assert 'repro_poolserver_verbs_total{verb="read_rows"} 3' in pool_txt
    assert 'repro_poolserver_payload_bytes_total{dir="rx"} 10' in pool_txt
    # renderers work with tracing off too (no histogram section)
    TRACER.disable()
    off = render_prometheus({"n_requests": 0})
    assert "repro_span_seconds" not in off


def test_dump_trace_harvests_remote(pds, tmp_path):
    data, queries = pds
    srv = PoolServer()
    srv.start()
    try:
        TRACER.configure(trace_id=17)
        eng = DHNSWEngine(EngineConfig(**CFG, pool="remote",
                                       endpoints=(srv.endpoint,))
                          ).build(data)
        with SearchServer(eng, BatchPolicy(max_batch=8,
                                           max_wait_s=1e-3)) as ss:
            ss.search(queries[:2], k=5)
            path = tmp_path / "trace.json"
            n = ss.dump_trace(path)
        spans = load_trace(path)
        assert len(spans) == n
        assert any(s["tier"] == "server" for s in spans)
        eng.pool.close()
    finally:
        TRACER.disable()
        srv.stop()


# ------------------------------------------------------------ histograms


def test_latency_histogram_unit():
    h = LatencyHistogram()
    for v in (1e-6, 1e-5, 1e-4, 1e-3):
        h.record(v)
    assert h.count == 4
    assert h.sum_s == pytest.approx(1.111e-3)
    assert h.quantile(0.5) <= h.quantile(0.99)
    assert h.quantile(1.0) >= 1e-3
    h.record(1e4)                      # overflow bucket
    assert h.quantile(1.0) > HIST_BOUNDS[-1]
    other = LatencyHistogram()
    other.record(2e-4)
    h.merge(other)
    assert h.count == 6
    assert h.mean() == pytest.approx(h.sum_s / 6)
    back = LatencyHistogram.from_dict(h.to_dict())
    assert back.counts == h.counts and back.count == h.count
    assert back.sum_s == pytest.approx(h.sum_s)


def test_verb_shard_hist_and_straggler_detector():
    vh = VerbShardHist()
    for s in range(3):
        for _ in range(40):
            vh.record("read_spans", s, 1e-2 if s == 1 else 1e-5)
    det = StragglerDetector(min_count=32)
    rep = det.verdicts(vh)
    assert set(rep["flagged"]) == {1}
    info = rep["flagged"][1]
    assert info["verb"] == "read_spans"
    assert info["excess_s"] > 1e-3
    assert info["ratio"] > det.ratio
    back = VerbShardHist.from_dict(vh.to_dict())
    assert len(back) == len(vh)
    assert back.get("read_spans", 1).count == 40
    # a uniform fleet never flags; nor does one with too few samples
    uni = VerbShardHist()
    for s in range(3):
        for _ in range(40):
            uni.record("read_rows", s, 1e-5)
    uni.record("read_meta", 0, 5.0)    # single-shard verb: no fleet
    assert det.verdicts(uni)["flagged"] == {}


# ------------------------------------------------------------ injection


def test_wr_injector_deterministic_schedule():
    a = WRInjector(seed=7, delay_s=1e-4, spike_s=1e-3, spike_every=5)
    b = WRInjector(seed=7, delay_s=1e-4, spike_s=1e-3, spike_every=5)
    for _ in range(20):
        a.on_post([None])
        b.on_post([None])
    assert a.snapshot() == b.snapshot()
    # (i * MIX + 7) % 5 == 0 <=> i % 5 == 3: posts 3, 8, 13, 18 spike
    assert a.posts == 20 and a.injections == 20
    assert a.injected_s == pytest.approx(20 * 1e-4 + 4 * 1e-3)
    c = WRInjector(seed=8, spike_s=1e-3, spike_every=5)
    for _ in range(20):
        c.on_post([None])
    assert c.injections == 4           # seed shifts which posts spike
    assert c.injected_s == pytest.approx(4e-3)


def test_wr_injector_error_is_connection_error():
    e = WRInjector(seed=0, error_every=1)
    with pytest.raises(InjectedFault):
        e.on_post([None])
    assert e.faults == 1
    assert e.injected_s == 0.0         # failed posts charge nothing
    # the fault must flow through the existing failover handlers
    assert issubclass(InjectedFault, ConnectionError)


# ------------------------------------------------------------ tail sampling


def test_tail_sampler_keeps_interesting_roots():
    tr = Tracer()
    tr.configure(trace_id=41, tail=True, tail_quantile=0.9, tail_window=64)
    for _ in range(8):                 # no stable threshold yet: kept
        with tr.span("warm", tier="serve", model_s=0.010):
            pass
    assert tr.kept == 8
    assert all(s["attrs"]["why_kept"] == "warmup" for s in tr.snapshot())
    for _ in range(10):                # under threshold: whole trace drops
        with tr.span("fast", tier="serve", model_s=0.001):
            tr.event("child", tier="pool")
    assert tr.discarded == 10
    assert len(tr.snapshot()) == 8
    with tr.span("slow", tier="serve", model_s=0.050):
        tr.event("child", tier="pool")
    spans = tr.snapshot()
    root = [s for s in spans if s["name"] == "slow"]
    assert root and root[0]["attrs"]["why_kept"] == "latency"
    assert any(s["name"] == "child" for s in spans)   # staged child kept
    with tr.span("meh", tier="serve", model_s=0.001, keep=True):
        pass
    assert tr.snapshot()[-1]["attrs"]["why_kept"] == "marked"
    with tr.span("bad", tier="serve", model_s=0.001, error=1):
        pass
    assert tr.snapshot()[-1]["attrs"]["why_kept"] == "error"
    h = tr.health()
    assert h["tail"] == 1 and h["kept"] == tr.kept == 11
    assert h["discarded"] == 10 and h["threshold_s"] > 0.0


def test_tail_sampler_default_ring_semantics_unchanged():
    # tail off: the ring is still "last N spans", as the capacity test
    # and every pre-tail consumer assume
    tr = Tracer(capacity=4)
    tr.configure(trace_id=1)
    assert tr.tail is False
    for i in range(7):
        tr.event(f"e{i}")
    assert [s["name"] for s in tr.snapshot()] == ["e3", "e4", "e5", "e6"]
    assert tr.health()["dropped"] == 3


# ------------------------------------------------------------ SLOs


def test_slo_parse_and_burn_rate():
    slo = parse_slo("p99<5ms")
    assert slo.quantile == pytest.approx(0.99)
    assert slo.threshold_s == pytest.approx(5e-3)
    assert slo.budget == pytest.approx(0.01)
    assert parse_slo("P95 < 250US").threshold_s == pytest.approx(250e-6)
    assert parse_slo(SLO(0.5, 1.0)) == SLO(0.5, 1.0)
    for bad in ("99<5ms", "p0<5ms", "p100<5ms", "p99<5min", "p99"):
        with pytest.raises(ValueError):
            parse_slo(bad)

    t = SLOTracker("p90<1ms", short_window=4, long_window=16)
    for _ in range(12):
        t.record("serve", "a", 1e-4)
    t.record("fetch", "a", 9.9)        # unconfigured tier: no-op
    r = t.report()["serve"]["a"]
    assert r["n"] == 12 and r["violations"] == 0
    assert r["burn"] == 0.0 and r["met"] is True
    for _ in range(4):                 # sustained violation
        t.record("serve", "a", 5e-3)
    r = t.report()["serve"]["a"]
    # short window all-bad: burn = 1.0 / budget(0.1); long smooths it
    assert r["burn_short"] == pytest.approx(10.0)
    assert r["burn_long"] == pytest.approx((4 / 16) / 0.1)
    assert r["burn"] == pytest.approx(2.5)   # multi-window AND: the min
    assert r["violations"] == 4 and r["met"] is False


# ------------------------------------------------------------ chaos e2e


def test_straggler_detected_and_routed_around(pds):
    data, queries = pds
    kw = dict(CFG, pool="sharded", shard_transport="sim_rdma", n_shards=3,
              replication=2, fabric=RDMA_100G)
    ref = DHNSWEngine(EngineConfig(**kw)).build(data)
    d0a, g0a, _ = ref.search(queries[:8], k=5)
    d0b, g0b, _ = ref.search(queries[8:], k=5)

    TRACER.configure(trace_id=51, tail=True, tail_window=64)
    eng = DHNSWEngine(EngineConfig(**kw)).build(data)
    eng.pool.straggler = StragglerDetector(min_count=4, min_excess_s=1e-4)
    for _ in range(3):                            # warm: healthy fleet
        d1, g1, _ = eng.search(queries[:8], k=5)
    assert eng.pool.check_stragglers()["flagged"] == {}

    inj = WRInjector(seed=7, delay_s=2e-3)
    eng.pool.children[1].set_injector(inj)
    for _ in range(3):
        d2, g2, _ = eng.search(queries[8:], k=5)
    assert inj.posts > 0
    rep = eng.pool.check_stragglers()
    assert set(rep["flagged"]) == {1}             # exactly the slow shard
    assert rep["flagged"][1]["excess_s"] >= 1e-4
    # the flagged shard loses every serving slot to a healthy replica
    assert not np.any(eng.pool._serve == 1)

    posts_before = inj.posts
    spans_before = eng.pool.verbs.get("read_spans", 0)
    d3, g3, _ = eng.search(queries[8:], k=5)
    assert eng.pool.verbs["read_spans"] > spans_before
    assert inj.posts == posts_before              # routed around shard 1

    # chaos + tail tracing never changes results
    for d, g, dr, gr in ((d1, g1, d0a, g0a), (d2, g2, d0b, g0b),
                         (d3, g3, d0b, g0b)):
        assert np.array_equal(np.asarray(d), np.asarray(dr))
        assert np.array_equal(np.asarray(g), np.asarray(gr))

    st = eng.pool.snapshot()
    assert st["stragglers"]["flagged_now"] == 1
    assert st["stragglers"]["reroutes"] >= 1
    assert st["stragglers"]["moved_groups"] >= 1
    assert st["stragglers"]["penalty_s"]["1"] >= 1e-4
    assert "read_spans" in st["hist"]


def test_slo_and_metrics_with_dead_shard(pds):
    data, queries = pds
    kw = dict(CFG, pool="sharded", shard_transport="sim_rdma", n_shards=3,
              replication=2)
    eng = DHNSWEngine(EngineConfig(**kw)).build(data)
    pol = BatchPolicy(max_batch=8, max_wait_s=1e-3, slo="p99<5ms",
                      slo_short_window=4)
    with SearchServer(eng, pol) as srv:
        srv.search(queries[:4], k=5)
        eng.pool._on_shard_down(1)
        srv.search(queries[4:8], k=5)

        # a child that dies mid-harvest is counted, never raised
        def _dead_harvest():
            raise PoolUnavailableError("shard died mid-drain")
        eng.pool.children[0].harvest_trace = _dead_harvest
        assert eng.pool.harvest_trace() == 0
        assert eng.pool.trace_harvest_failures == 1
        srv.search(queries[8:12], k=5)    # refresh the pool snapshot

        st = srv.stats()
        r = st["slo"]["serve"]["-"]
        assert r["n"] >= 2
        assert {"burn", "burn_short", "burn_long", "attainment",
                "met"} <= set(r)
        assert r["threshold_ms"] == pytest.approx(5.0)
        assert st["failover"]["trace_harvest_failures"] == 1
        assert st["failover"]["alive_shards"] == 2
        assert "stragglers" in st
        txt = srv.metrics_text()
    for family in ("repro_slo", "repro_pool_verb_latency_seconds_bucket",
                   "repro_tracer", "repro_straggler",
                   "repro_failover"):
        assert family in txt, family
    for line in txt.strip().splitlines():
        if not line.startswith("#"):
            float(line.rsplit(" ", 1)[1])


def test_pool_server_service_histograms(pds):
    data, queries = pds
    eng = DHNSWEngine(EngineConfig(**CFG, pool="remote",
                                   bearer="loopback")).build(data)
    eng.search(queries[:4], k=5)
    st = eng.pool.server_stats()
    assert st["service_hist"]
    for verb, series in st["service_hist"].items():
        assert series["count"] >= 1
        assert verb in st["service_s"]
    txt = render_pool_server(st)
    assert "repro_poolserver_service_seconds_bucket" in txt
    assert "repro_poolserver_service_seconds_count" in txt
    for line in txt.strip().splitlines():
        if not line.startswith("#"):
            float(line.rsplit(" ", 1)[1])
    eng.pool.close()


# ------------------------------------------------------------ determinism


def test_counted_pass_deterministic(sift_small):
    """Back-to-back counted passes must emit identical gated metrics —
    the contract benchmarks/perf_gate.py's serving gate stands on."""
    sys.path.insert(0, str(Path(__file__).resolve().parent.parent
                           / "benchmarks"))
    try:
        import serving
    finally:
        sys.path.pop(0)
    data, queries = sift_small.data[:1200], sift_small.queries[:16]
    a = serving.counted_pass("full", data, queries, n_rep=12, C=3, k=5,
                             waves=2, seed=0)
    b = serving.counted_pass("full", data, queries, n_rep=12, C=3, k=5,
                             waves=2, seed=0)
    assert json.dumps(a, sort_keys=True) == json.dumps(b, sort_keys=True)
    fused = {r["impl"]: r["mean_fused_batch"] for r in a}
    assert fused == {"serial": 1.0, "batched": 3.0}
