"""Fig. 6 reproduction: latency-recall curves, 3 schemes x 2 datasets
x top-{1,10}, efSearch 1..48.

Latency per query = network (cost model, RDMA fabric) + measured
sub-HNSW + meta-HNSW compute, / batch.  The paper's claims checked here:
  * recall rises with efSearch toward ~0.85+ and saturates;
  * naive latency / d-HNSW latency ~ O(100x) (117x in the paper);
  * w/o doorbell sits between, ~1.1-1.3x above full d-HNSW.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import P, batched_queries, dataset, emit, engine
from repro.core.hnsw import recall_at_k


def run(datasets=("sift", "gist"), topks=(10, 1)) -> list[dict]:
    rows = []
    for name in datasets:
        ds = dataset(name)
        queries = batched_queries(ds, P["batch"])
        for topk in topks:
            for mode in ("naive", "no_doorbell", "full"):
                eng = engine(name, mode)
                for ef in P["efs"]:
                    # cold-ish cache per (mode, ef) point: reuse engine,
                    # cache persists across points exactly like the
                    # paper's steady-state serving loop
                    d, g, st = eng.search(queries, k=topk, ef=ef)
                    n = min(len(g), len(ds.queries))
                    rec = recall_at_k(g[:n], ds.gt_ids[:n, :topk])
                    net_s = st["net"]["latency_s"]
                    total = net_s + st["sub_s"] + st["meta_s"]
                    row = dict(
                        name=f"fig6/{name}@top{topk}/{mode}/ef{ef}",
                        us_per_call=round(total / len(queries) * 1e6, 2),
                        recall=round(rec, 4),
                        net_us_q=round(net_s / len(queries) * 1e6, 3),
                        sub_us_q=round(st["sub_s"] / len(queries) * 1e6, 1),
                        meta_us_q=round(st["meta_s"] / len(queries) * 1e6, 1),
                        rtpq=round(st["round_trips_per_query"], 5))
                    rows.append(row)
                    emit(dict(row))
    # headline ratio check (ef=48, top-10): the paper's 117x/121x is a
    # NETWORK-term ratio under NIC queueing; we report the linear-model
    # network ratio (no queueing -> a conservative lower bound) plus the
    # total-latency ratio for completeness
    by = {r["name"]: r for r in rows}
    for name in datasets:
        n = by.get(f"fig6/{name}@top10/naive/ef48")
        f = by.get(f"fig6/{name}@top10/full/ef48")
        nd = by.get(f"fig6/{name}@top10/no_doorbell/ef48")
        if n and f:
            emit(dict(name=f"fig6/{name}/headline",
                      us_per_call="",
                      naive_over_full_net=round(
                          n["net_us_q"] / max(f["net_us_q"], 1e-9), 1),
                      nodoorbell_over_full_net=round(
                          nd["net_us_q"] / max(f["net_us_q"], 1e-9), 2),
                      naive_over_full_total=round(
                          n["us_per_call"] / max(f["us_per_call"], 1e-9), 1),
                      recall_at_ef48=f["recall"]))
    return rows


def main():
    run()


if __name__ == "__main__":
    main()
