"""Offered-load sweep: serial per-request submission vs micro-batched.

For each scheme (naive / no_doorbell / full) and concurrency level C,
C closed-loop client threads each issue single-query requests:

  * ``serial``  — every request is its own ``engine.search`` call
                  (lock-serialized; the engine is single-writer).  This
                  is what a serving tier WITHOUT cross-request batching
                  does: no partition dedup across users, fixed
                  route/plan/dispatch overheads paid per request.
  * ``batched`` — requests go through ``serve.MicroBatcher``; concurrent
                  requests fuse into one engine batch, so §3.3 dedup,
                  doorbell grouping, and cache reuse amortize across
                  requesters.

Two passes per (mode, C):

  * a DETERMINISTIC counted pass (the ``counted`` table): a single
    submitter issues waves of exactly C requests against a batcher with
    ``max_batch=C`` and an effectively-infinite window, so every fused
    window is exactly the wave in submission order.  Per-query round
    trips / descriptors / KB come from the NetLedger and
    ``mean_fused_batch`` from the batcher — no wall clock anywhere, so
    these rows are gated by ``benchmarks/perf_gate.py``.
  * the wall-clock sweep (the ``rows`` table): closed-loop client
    threads, throughput + latency percentiles.  Timing-dependent, never
    gated (the observed fusion is reported as ``fused_batch_obs``).

``--trace FILE`` additionally records the wall-clock sweep through
``repro.obs``: measured serial/batched sections are phase-tagged, every
request gets a ``request`` bench span, and the Chrome-trace JSON is
written to FILE for ``python -m repro.obs.report`` (this is how the
batched-vs-serial gap gets its stage-level diagnosis).

Emits ``BENCH_serving.json`` for the perf-trajectory file.  ``--smoke``
runs a tiny CI-sized config; its wall-clock side only has to not crash,
its counted side must match ``benchmarks/baselines/BENCH_serving.json``.
"""
from __future__ import annotations

import argparse
import json
import threading
import time

import numpy as np

from repro.core import DHNSWEngine, EngineConfig
from repro.core.cost_model import RDMA_100G
from repro.data.synthetic import sift_like
from repro.obs.trace import TRACER
from repro.serve.batcher import BatchPolicy, MicroBatcher


def build_engine(mode: str, data: np.ndarray, n_rep: int,
                 seed: int = 0) -> DHNSWEngine:
    cfg = EngineConfig(mode=mode, search_mode="scan", b=3, ef=32,
                       n_rep=n_rep, cache_frac=0.15, doorbell=16,
                       fabric=RDMA_100G, seed=seed)
    return DHNSWEngine(cfg).build(data)


def _percentiles(lat: list[float]) -> dict:
    arr = np.asarray(lat, np.float64) * 1e3
    return {f"p{p}_ms": round(float(np.percentile(arr, p)), 3)
            for p in (50, 95, 99)}


def _per_q(tot: dict, nq: int) -> dict:
    """Ledger totals -> the gated per-query metrics."""
    return {"round_trips_per_q": round(tot["round_trips"] / nq, 4),
            "descriptors_per_q": round(tot["descriptors"] / nq, 4),
            "kb_per_q": round(tot["bytes"] / nq / 1024.0, 4)}


def counted_pass(mode: str, data, queries, *, n_rep: int, C: int, k: int,
                 waves: int, seed: int) -> list[dict]:
    """Deterministic serial-vs-batched comparison at concurrency C.

    Both impls see the same request stream (``waves`` waves of C
    single-query requests, queries cycled in submission order) on a
    FRESH engine each, so cache state evolves identically run to run.
    The batcher is pinned to ``max_batch=C`` with a huge window: the
    dispatcher only closes a window at C rows, so every wave fuses into
    exactly one engine call and ``mean_fused_batch == C`` by
    construction — any drift is a scheduling regression.
    """
    nq = C * waves

    eng = build_engine(mode, data, n_rep, seed=seed)
    tot = {"round_trips": 0.0, "descriptors": 0.0, "bytes": 0.0}
    for i in range(nq):
        _, _, st = eng.search(queries[i % len(queries)][None], k=k)
        for key in tot:
            tot[key] += float(st["net"][key])
    rows = [{"impl": "serial", **_per_q(tot, nq), "mean_fused_batch": 1.0}]

    eng = build_engine(mode, data, n_rep, seed=seed)
    with MicroBatcher(eng, BatchPolicy(max_batch=C,
                                       max_wait_s=30.0)) as mb:
        for w in range(waves):
            futs = [mb.submit_search(queries[(w * C + i) % len(queries)],
                                     k=k) for i in range(C)]
            for f in futs:
                f.result()
        snap = mb.metrics.snapshot()
    net = snap["net"]
    rows.append({"impl": "batched",
                 **_per_q({"round_trips": net["round_trips"],
                           "descriptors": net["descriptors"],
                           "bytes": net["bytes_fetched"]}, nq),
                 "mean_fused_batch":
                     round(float(snap["mean_fused_batch"]), 2)})
    return rows


def run_clients(n_clients: int, per_client: int, queries: np.ndarray,
                call) -> dict:
    """Closed loop: each client thread issues its requests back-to-back."""
    lat: list[list[float]] = [[] for _ in range(n_clients)]
    errs: list[BaseException] = []

    def client(cid: int):
        rng = np.random.default_rng(cid)
        try:
            for _ in range(per_client):
                q = queries[rng.integers(0, len(queries))]
                t0 = time.perf_counter()
                call(q)
                lat[cid].append(time.perf_counter() - t0)
        except BaseException as e:      # surface, don't hang the join
            errs.append(e)

    threads = [threading.Thread(target=client, args=(c,))
               for c in range(n_clients)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t0
    if errs:
        raise errs[0]
    flat = [x for l in lat for x in l]
    return {"qps": round(len(flat) / wall, 1), "wall_s": round(wall, 3),
            **_percentiles(flat)}


def sweep(mode: str, data, queries, *, n_rep: int, clients: tuple[int, ...],
          per_client: int, k: int, seed: int = 0) -> list[dict]:
    eng = build_engine(mode, data, n_rep, seed=seed)
    lock = threading.Lock()

    def serial_call(q):
        with TRACER.span("request", tier="bench", impl="serial"):
            with lock:
                eng.search(q[None], k=k)

    rows = []
    warm = max(2, per_client // 3)
    for C in clients:
        # steady-state measurement: the jitted engine stages specialize on
        # (fused batch, round pad, merge lanes) shapes, so drive enough
        # warmup traffic through BOTH paths that measured windows reuse
        # compiled code, as a long-running server does
        TRACER.set_phase("warmup")
        run_clients(C, warm, queries, serial_call)
        TRACER.set_phase("serial")
        serial = run_clients(C, per_client, queries, serial_call)
        with MicroBatcher(eng, BatchPolicy(max_batch=max(64, 2 * C),
                                           max_wait_s=4e-3)) as mb:
            def batched_call(q):
                with TRACER.span("request", tier="bench", impl="batched"):
                    mb.search(q, k=k)

            TRACER.set_phase("warmup")
            run_clients(C, 2 * warm, queries, batched_call)
            TRACER.set_phase("batched")
            batched = run_clients(C, per_client, queries, batched_call)
            fused = mb.metrics.snapshot()["mean_fused_batch"]
        TRACER.set_phase(None)
        speedup = round(batched["qps"] / max(serial["qps"], 1e-9), 2)
        for impl, res in (("serial", serial), ("batched", batched)):
            rows.append({"mode": mode, "clients": C, "impl": impl, **res})
        # observed fusion under wall-clock timing — informational only;
        # the deterministic counterpart in the ``counted`` table is gated
        rows[-1]["fused_batch_obs"] = round(fused, 2)
        rows[-1]["speedup_vs_serial"] = speedup
        print(f"{mode:12s} C={C:3d}  serial {serial['qps']:8.1f} qps "
              f"(p95 {serial['p95_ms']:7.1f} ms) | batched "
              f"{batched['qps']:8.1f} qps (p95 {batched['p95_ms']:7.1f} ms) "
              f"| fused~{fused:.1f}  speedup x{speedup}", flush=True)
    return rows


def run(*, smoke: bool = False, out: str = "BENCH_serving.json",
        modes=("naive", "no_doorbell", "full"), seed: int = 0,
        trace_out: str | None = None, skip_wallclock: bool = False) -> dict:
    if smoke:
        n, n_rep, clients, per_client, waves = 2000, 16, (1, 4), 4, 2
        modes = ["full"]
    else:
        n, n_rep, clients, per_client, waves = (20_000, 64, (1, 4, 8, 16),
                                                25, 3)
    ds = sift_like(n=n, n_queries=64, seed=seed)

    counted = []
    for mode in modes:
        for C in clients:
            for row in counted_pass(mode, ds.data, ds.queries, n_rep=n_rep,
                                    C=C, k=10, waves=waves, seed=seed):
                counted.append({"mode": mode, "clients": C, **row})
            b, s = counted[-1], counted[-2]
            print(f"counted {mode:12s} C={C:3d}  trips/q "
                  f"{s['round_trips_per_q']:7.2f} -> "
                  f"{b['round_trips_per_q']:7.2f}  KB/q "
                  f"{s['kb_per_q']:8.2f} -> {b['kb_per_q']:8.2f}  "
                  f"fused {b['mean_fused_batch']:.2f}", flush=True)

    if trace_out:
        TRACER.configure()
    rows = []
    if not skip_wallclock:
        for mode in modes:
            rows.extend(sweep(mode, ds.data, ds.queries, n_rep=n_rep,
                              clients=clients, per_client=per_client, k=10,
                              seed=seed))
    if trace_out:
        n_spans = TRACER.save(trace_out)
        TRACER.disable()
        print(f"wrote {trace_out} ({n_spans} spans) — inspect with "
              f"`python -m repro.obs.report {trace_out}`")

    blob = {"bench": "serving", "smoke": smoke, "n": n, "seed": seed,
            "clients": list(clients), "per_client": per_client,
            "waves": waves, "counted": counted, "rows": rows}
    with open(out, "w") as f:
        json.dump(blob, f, indent=2)
    print(f"wrote {out} ({len(counted)} counted + {len(rows)} rows)")
    return blob


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI config; counted rows are perf-gated, "
                         "wall-clock rows are crash-check only")
    ap.add_argument("--out", default="BENCH_serving.json")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace", default=None, metavar="FILE",
                    help="record the wall-clock sweep with repro.obs and "
                         "write Chrome-trace JSON to FILE")
    ap.add_argument("--modes", nargs="*",
                    default=["naive", "no_doorbell", "full"])
    args = ap.parse_args()
    run(smoke=args.smoke, out=args.out, modes=args.modes, seed=args.seed,
        trace_out=args.trace)


if __name__ == "__main__":
    main()
