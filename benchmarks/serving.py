"""Offered-load sweep: serial per-request submission vs micro-batched.

For each scheme (naive / no_doorbell / full) and concurrency level C,
C closed-loop client threads each issue single-query requests:

  * ``serial``  — every request is its own ``engine.search`` call
                  (lock-serialized; the engine is single-writer).  This
                  is what a serving tier WITHOUT cross-request batching
                  does: no partition dedup across users, fixed
                  route/plan/dispatch overheads paid per request.
  * ``batched`` — requests go through ``serve.MicroBatcher``; concurrent
                  requests fuse into one engine batch, so §3.3 dedup,
                  doorbell grouping, and cache reuse amortize across
                  requesters.

Emits throughput + latency percentiles per (mode, C, impl) and writes
``BENCH_serving.json`` for the perf-trajectory file.  ``--smoke`` runs a
tiny CI-sized config whose only job is to exercise the path end-to-end
(fails on crash, never on perf).
"""
from __future__ import annotations

import argparse
import json
import threading
import time

import numpy as np

from repro.core import DHNSWEngine, EngineConfig
from repro.core.cost_model import RDMA_100G
from repro.data.synthetic import sift_like
from repro.serve.batcher import BatchPolicy, MicroBatcher


def build_engine(mode: str, data: np.ndarray, n_rep: int) -> DHNSWEngine:
    cfg = EngineConfig(mode=mode, search_mode="scan", b=3, ef=32,
                       n_rep=n_rep, cache_frac=0.15, doorbell=16,
                       fabric=RDMA_100G, seed=0)
    return DHNSWEngine(cfg).build(data)


def _percentiles(lat: list[float]) -> dict:
    arr = np.asarray(lat, np.float64) * 1e3
    return {f"p{p}_ms": round(float(np.percentile(arr, p)), 3)
            for p in (50, 95, 99)}


def run_clients(n_clients: int, per_client: int, queries: np.ndarray,
                call) -> dict:
    """Closed loop: each client thread issues its requests back-to-back."""
    lat: list[list[float]] = [[] for _ in range(n_clients)]
    errs: list[BaseException] = []

    def client(cid: int):
        rng = np.random.default_rng(cid)
        try:
            for _ in range(per_client):
                q = queries[rng.integers(0, len(queries))]
                t0 = time.perf_counter()
                call(q)
                lat[cid].append(time.perf_counter() - t0)
        except BaseException as e:      # surface, don't hang the join
            errs.append(e)

    threads = [threading.Thread(target=client, args=(c,))
               for c in range(n_clients)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t0
    if errs:
        raise errs[0]
    flat = [x for l in lat for x in l]
    return {"qps": round(len(flat) / wall, 1), "wall_s": round(wall, 3),
            **_percentiles(flat)}


def sweep(mode: str, data, queries, *, n_rep: int, clients: tuple[int, ...],
          per_client: int, k: int) -> list[dict]:
    eng = build_engine(mode, data, n_rep)
    lock = threading.Lock()

    def serial_call(q):
        with lock:
            eng.search(q[None], k=k)

    rows = []
    warm = max(2, per_client // 3)
    for C in clients:
        # steady-state measurement: the jitted engine stages specialize on
        # (fused batch, round pad, merge lanes) shapes, so drive enough
        # warmup traffic through BOTH paths that measured windows reuse
        # compiled code, as a long-running server does
        run_clients(C, warm, queries, serial_call)
        serial = run_clients(C, per_client, queries, serial_call)
        with MicroBatcher(eng, BatchPolicy(max_batch=max(64, 2 * C),
                                           max_wait_s=4e-3)) as mb:
            run_clients(C, 2 * warm, queries, lambda q: mb.search(q, k=k))
            batched = run_clients(C, per_client, queries,
                                  lambda q: mb.search(q, k=k))
            fused = mb.metrics.snapshot()["mean_fused_batch"]
        speedup = round(batched["qps"] / max(serial["qps"], 1e-9), 2)
        for impl, res in (("serial", serial), ("batched", batched)):
            rows.append({"mode": mode, "clients": C, "impl": impl, **res})
        rows[-1]["mean_fused_batch"] = round(fused, 2)
        rows[-1]["speedup_vs_serial"] = speedup
        print(f"{mode:12s} C={C:3d}  serial {serial['qps']:8.1f} qps "
              f"(p95 {serial['p95_ms']:7.1f} ms) | batched "
              f"{batched['qps']:8.1f} qps (p95 {batched['p95_ms']:7.1f} ms) "
              f"| fused~{fused:.1f}  speedup x{speedup}", flush=True)
    return rows


def run(*, smoke: bool = False, out: str = "BENCH_serving.json",
        modes=("naive", "no_doorbell", "full")) -> list[dict]:
    if smoke:
        n, n_rep, clients, per_client = 2000, 16, (1, 4), 4
        modes = ["full"]
    else:
        n, n_rep, clients, per_client = 20_000, 64, (1, 4, 8, 16), 25
    ds = sift_like(n=n, n_queries=64, seed=0)

    rows = []
    for mode in modes:
        rows.extend(sweep(mode, ds.data, ds.queries, n_rep=n_rep,
                          clients=clients, per_client=per_client, k=10))

    blob = {"bench": "serving", "smoke": smoke, "n": n,
            "clients": list(clients), "per_client": per_client, "rows": rows}
    with open(out, "w") as f:
        json.dump(blob, f, indent=2)
    print(f"wrote {out} ({len(rows)} rows)")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI config; crash-check only")
    ap.add_argument("--out", default="BENCH_serving.json")
    ap.add_argument("--modes", nargs="*",
                    default=["naive", "no_doorbell", "full"])
    args = ap.parse_args()
    run(smoke=args.smoke, out=args.out, modes=args.modes)


if __name__ == "__main__":
    main()
