"""Perf gate: fail CI when a fresh smoke bench regresses vs baseline.

The BENCH_*.json trajectory was write-only — every CI run uploaded the
smoke blobs as artifacts and nobody compared them.  This gate closes
the loop: committed baselines live in ``benchmarks/baselines/`` and a
fresh run must stay within ``--threshold`` (default 25%) of them.

Only DETERMINISTIC metrics are gated — counted verbs (round trips,
descriptors, bytes) and the fabric-model time they price to, plus
recall.  Wall-clock fields (``wall_s``, ``qps``, ``p*_ms``) vary with
the runner and are never compared.  ``BENCH_serving.json`` gates
through its ``counted`` table (ledger-derived per-query verbs and the
pinned-window ``mean_fused_batch`` from ``benchmarks/serving.py``'s
deterministic pass); its wall-clock ``rows`` table stays crash-check
only.  On this codebase the gated metrics are exactly
reproducible, so the 25% slack only exists to let intentional small
workload tweaks through — any real change should refresh the baseline
in the same PR (run the smoke bench, copy the blob over, review the
diff).

Matching: rows are keyed by every scalar field that is not a gated or
ignored metric (mode/quant/fabric/placement/...).  A baseline row with
no fresh counterpart fails the gate — silently dropped coverage is a
regression too; fresh rows with no baseline (new coverage) pass.

Usage (CI runs it after the smoke benches, from the repo root):

    python benchmarks/perf_gate.py
    python benchmarks/perf_gate.py --threshold 0.10 BENCH_pool.json
"""
from __future__ import annotations

import argparse
import json
import os
import sys

# metric -> direction a REGRESSION moves; everything else is identity
# or ignored.  "up" = bigger is worse (bytes, trips, modeled time);
# "down" = smaller is worse (recall, dedup savings).
GATED = {
    "round_trips_per_q": "up", "descriptors_per_q": "up",
    "kb_per_q": "up", "model_kb_per_q": "up", "wire_kb_per_q": "up",
    "sim_us_per_q": "up", "byte_imbalance": "up",
    "round_trips": "up", "mbytes": "up", "rereplicate_mb": "up",
    "recall": "down", "mbytes_saved": "down", "id_match": "down",
    # deterministic by construction in serving.py's counted pass (the
    # batcher window is pinned to the wave size) — smaller fused windows
    # mean the serving tier stopped coalescing
    "mean_fused_batch": "down",
    # ingest (benchmarks/ingest.py): the bulk loader's memory ceiling
    # and shipping verb count, and the durable server's WAL/checkpoint
    # footprint — all deterministic functions of the workload.  Fewer
    # replayed records means recovery stopped riding the WAL.
    "peak_builder_mb": "up", "verbs_issued": "up", "chunks_failed": "up",
    "wal_records": "up", "wal_kb": "up", "checkpoint_kb": "up",
    "replayed_records": "down",
    # 1/N block-compacted staging (pool shard rows): the largest
    # per-shard staged device footprint is a deterministic function of
    # placement — growing means compaction stopped holding ~1/N
    "staged_mb_max": "up",
    # straggler-chaos observability (pool chaos_latency row) — all
    # modeled-clock functions of the seeded WR schedule.  Fewer kept /
    # latency-kept traces means the tail sampler stopped promoting the
    # slow batches; zero detector flags means the straggler detector
    # went blind; a rising p99 or cut ratio means replica-ranked reads
    # stopped routing around the injected shard; a fallen burn peak
    # means the SLO engine stopped seeing the injected violations.
    "kept_traces": "down", "why_kept_latency": "down",
    "detector_flags": "down", "p99_cut_ratio": "up",
    "p99_on_us": "up", "burn_peak": "down",
}
# measured on the runner's clock, or incidental detail — never gated
IGNORED = frozenset({
    "wall_s", "qps", "p50_ms", "p95_ms", "p99_ms", "kill_batch_ms",
    "wire_frames", "wire_frame_overhead_kb", "span_wire_vs_model",
    "migrations", "fused_batch_obs", "speedup_vs_serial", "endpoint",
    "pallas_us", "ref_us", "deaths", "read_retries",
    "rereplicated_groups", "lost_groups", "recover_wall_s",
    "inflight_peak", "restaged_blocks",
    # chaos_latency incidentals: deterministic but either redundant with
    # a gated ratio (p99_off_us) or free to drift with workload detail
    # (check cadence, ring pressure, exact reroute point)
    "p99_off_us", "discarded_traces", "reroute_batch", "checks",
    "moved_groups", "injected_posts", "ring_dropped",
})


def row_key(row: dict) -> tuple:
    """Identity of a bench row: its non-metric scalar fields."""
    return tuple(sorted((k, v) for k, v in row.items()
                        if k not in GATED and k not in IGNORED
                        and isinstance(v, (str, bool, int, float))))


def compare_rows(where: str, base: dict, fresh: dict,
                 threshold: float) -> list[str]:
    fails = []
    for metric, direction in GATED.items():
        if metric not in base or metric not in fresh:
            continue
        b, f = float(base[metric]), float(fresh[metric])
        if direction == "up":
            bad = f > b * (1.0 + threshold) + 1e-9
        else:
            bad = f < b * (1.0 - threshold) - 1e-9
        if bad:
            fails.append(f"{where}: {metric} {b:g} -> {f:g} "
                         f"({(f - b) / max(abs(b), 1e-12):+.0%})")
    return fails


def iter_tables(blob: dict):
    """Yield (name, rows) for every row table in a bench blob; a bare
    metrics dict (e.g. the pool chaos row) counts as a 1-row table."""
    for name, val in blob.items():
        if isinstance(val, list) and val and all(
                isinstance(r, dict) for r in val):
            yield name, val
        elif isinstance(val, dict) and any(k in GATED for k in val):
            yield name, [val]


def gate_file(name: str, base_path: str, fresh_path: str,
              threshold: float) -> list[str]:
    with open(base_path) as f:
        base = json.load(f)
    try:
        with open(fresh_path) as f:
            fresh = json.load(f)
    except OSError:
        return [f"{name}: fresh blob missing at {fresh_path} — did the "
                f"smoke bench run?"]
    fails = []
    fresh_tables = dict(iter_tables(fresh))
    for tname, base_rows in iter_tables(base):
        fresh_rows = {row_key(r): r for r in fresh_tables.get(tname, [])}
        for brow in base_rows:
            key = row_key(brow)
            frow = fresh_rows.get(key)
            where = f"{name}:{tname}[{', '.join(f'{k}={v}' for k, v in key)}]"
            if frow is None:
                fails.append(f"{where}: baseline row has no fresh "
                             f"counterpart (workload changed? refresh "
                             f"benchmarks/baselines/)")
                continue
            fails.extend(compare_rows(where, brow, frow, threshold))
    return fails


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("blobs", nargs="*",
                    default=["BENCH_pool.json", "BENCH_quant.json",
                             "BENCH_serving.json", "BENCH_ingest.json"],
                    help="bench blob filenames to gate (must exist in "
                         "--baseline-dir)")
    ap.add_argument("--baseline-dir", default="benchmarks/baselines")
    ap.add_argument("--fresh-dir", default=".")
    ap.add_argument("--threshold", type=float, default=0.25,
                    help="relative regression that fails the gate")
    args = ap.parse_args()
    all_fails = []
    for name in args.blobs:
        base_path = os.path.join(args.baseline_dir, name)
        if not os.path.exists(base_path):
            print(f"perf-gate: no baseline for {name} "
                  f"({base_path} missing), skipping")
            continue
        fails = gate_file(name, base_path,
                          os.path.join(args.fresh_dir, name),
                          args.threshold)
        status = "FAIL" if fails else "ok"
        print(f"perf-gate: {name}: {status}")
        all_fails.extend(fails)
    for line in all_fails:
        print(f"  REGRESSION {line}")
    if all_fails:
        print(f"perf-gate: {len(all_fails)} regression(s) beyond "
              f"{args.threshold:.0%} — if intentional, refresh "
              f"benchmarks/baselines/ in this PR")
        return 1
    print("perf-gate: all gated metrics within threshold")
    return 0


if __name__ == "__main__":
    sys.exit(main())
