"""Layer-exact HLO costing for the roofline (fixes scan undercounting).

``compiled.cost_analysis()`` counts a ``lax.scan`` body ONCE regardless
of trip count, so costing the full model underreports per-layer work by
~n_layers x.  Fix: compile the model at ONE and TWO layer-units with the
unit scans *unrolled* (a unit = the smallest repeating block: 1 layer;
2 for gemma2's local/global pair; ``attn_every`` mamba blocks + 1 shared
attn for zamba2; 1 enc + 1 dec layer for whisper), then extrapolate

    cost(L) = cost(1u) + (units - 1) * (cost(2u) - cost(1u))

— the diff cancels the embed/unembed/loss epilogue exactly and counts
each additional unit exactly once.  Everything still comes from compiled
artifacts on the production (16,16) mesh, so flops/bytes are per-device
and the parsed collectives carry the real SPMD schedule.

Two passes per cell:
  A "flops": full (einsum) attention — exact matmul flops, every scan
     with trips<=128 unrolled (covers CE chunks, SSD chunks).
  B "bytes/collectives": the real flash path, scans with trips<=8
     unrolled (layer scans, 4k flash blocks); long-trip inner scans stay
     rolled -> attention/CE streaming bytes at 32k are a documented
     undercount (weights dominate those cells).

Writes results/hlo_cost.jsonl; benchmarks/roofline.py consumes it.
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=256 "
    + os.environ.get("XLA_FLAGS", ""))

import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
from jax import lax  # noqa: E402

_ORIG_SCAN = lax.scan
_UNROLL_LIMIT = {"limit": 8}


def _selective_unroll_scan(f, init, xs=None, length=None, **kw):
    import jax.numpy as jnp
    n = length
    if n is None and xs is not None:
        leaves = jax.tree.leaves(xs)
        if leaves:
            n = leaves[0].shape[0]
    if n is not None and n <= _UNROLL_LIMIT["limit"]:
        kw["unroll"] = True
    return _ORIG_SCAN(f, init, xs, length=length, **kw)


def _patch_scan():
    lax.scan = _selective_unroll_scan
    jax.lax.scan = _selective_unroll_scan


from repro.configs.base import SHAPES, InputShape, shape_applicable  # noqa: E402
from repro.configs.registry import ARCH_IDS, get_config  # noqa: E402
from repro.launch.dryrun import parse_collectives  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import model as M  # noqa: E402
from repro.train.train_step import make_step  # noqa: E402


def unit_layers(cfg) -> int:
    if cfg.family == "hybrid":
        return max(cfg.attn_every, 1)
    if cfg.local_global_pattern:
        return 2
    return 1


def n_units(cfg) -> int:
    return cfg.n_layers // unit_layers(cfg)


def cfg_at_units(cfg, units: int):
    u = unit_layers(cfg)
    kw = dict(n_layers=units * u)
    if cfg.family == "encdec":
        enc_per_unit = max(cfg.n_enc_layers // max(n_units(cfg), 1), 1)
        kw["n_enc_layers"] = units * enc_per_unit
    return cfg.replace(**kw)


def cost_one(cfg, shape, mesh) -> dict:
    fn, in_sh, out_sh, args = make_step(cfg, shape, mesh, micro_steps=1)
    with mesh:
        lowered = jax.jit(fn, in_shardings=in_sh,
                          out_shardings=out_sh).lower(*args)
        compiled = lowered.compile()
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    coll = parse_collectives(compiled.as_text())
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
            "wire": float(coll["wire_bytes_per_device"]),
            "coll_op_bytes": float(coll["operand_bytes_total"]),
            "n_coll": int(coll["n_collectives"])}


def extrapolate(c1: dict, c2: dict, units: int) -> dict:
    out = {}
    for k in ("flops", "bytes", "wire", "coll_op_bytes", "n_coll"):
        d = max(c2[k] - c1[k], 0.0)
        out[k] = c1[k] + (units - 1) * d
    return out


def run_cell(arch: str, shape_id: str) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_id]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_id, "status": "skipped",
                "reason": why}
    mesh = make_production_mesh(multi_pod=False)
    units = n_units(cfg)
    res = {"arch": arch, "shape": shape_id, "status": "ok",
           "units": units, "unit_layers": unit_layers(cfg),
           "n_devices": mesh.size,
           "model_flops": M.model_flops(cfg, shape)}
    t0 = time.time()
    # pass A: exact flops (full attention, deep unroll)
    os.environ["REPRO_FORCE_FULL_ATTENTION"] = "1"
    _UNROLL_LIMIT["limit"] = 128
    a1 = cost_one(cfg_at_units(cfg, 1), shape, mesh)
    a2 = cost_one(cfg_at_units(cfg, 2), shape, mesh)
    res["passA"] = extrapolate(a1, a2, units)
    # pass B: flash path bytes + collectives (shallow unroll)
    os.environ.pop("REPRO_FORCE_FULL_ATTENTION", None)
    _UNROLL_LIMIT["limit"] = 8
    b1 = cost_one(cfg_at_units(cfg, 1), shape, mesh)
    b2 = cost_one(cfg_at_units(cfg, 2), shape, mesh)
    res["passB"] = extrapolate(b1, b2, units)
    res["cost_s"] = round(time.time() - t0, 1)
    res["flops_dev"] = res["passA"]["flops"]
    res["bytes_dev"] = res["passB"]["bytes"]
    res["wire_dev"] = res["passB"]["wire"]
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--out", default="results/hlo_cost.jsonl")
    args = ap.parse_args()
    _patch_scan()

    archs = ARCH_IDS if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    done = set()
    if os.path.exists(args.out):
        for line in open(args.out):
            try:
                r = json.loads(line)
                if r.get("status") in ("ok", "skipped"):
                    done.add((r["arch"], r["shape"]))
            except Exception:
                pass
    for arch in archs:
        for shape_id in shapes:
            if (arch, shape_id) in done:
                print(f"[skip-done] {arch}/{shape_id}", flush=True)
                continue
            print(f"[cost] {arch}/{shape_id}", flush=True)
            try:
                res = run_cell(arch, shape_id)
            except Exception as e:  # noqa: BLE001
                res = {"arch": arch, "shape": shape_id, "status": "error",
                       "error": str(e)[:500],
                       "traceback": traceback.format_exc()[-2000:]}
            print(f"[done] {arch}/{shape_id} {res['status']} "
                  f"{res.get('cost_s', '')}s", flush=True)
            with open(args.out, "a") as f:
                f.write(json.dumps(res) + "\n")


if __name__ == "__main__":
    main()
