"""Shared benchmark scaffolding: datasets, engine builds, CSV emit.

Scale presets (env ``REPRO_BENCH_SCALE``):
  quick — CI-sized (default): sift 20k / gist 4k, batch 256
  full  — paper-shaped run on this box: sift 100k / gist 20k, batch 2000

The paper's absolute numbers come from 4x Xeon servers + 100 Gb RDMA; on
this container compute terms are CPU-measured (relative shape) and the
network term is priced by core/cost_model.py — the reproduction targets
are the paper's *ratios* (naive : no_doorbell : full) and recall curve.
"""
from __future__ import annotations

import functools
import os
import time

import numpy as np

from repro.core import DHNSWEngine, EngineConfig
from repro.core.cost_model import RDMA_100G, TPU_ICI
from repro.data.synthetic import gist_like, sift_like

SCALE = os.environ.get("REPRO_BENCH_SCALE", "quick")

PRESETS = {
    "quick": dict(sift_n=20_000, gist_n=4_000, n_queries=256, batch=256,
                  n_rep=128, efs=(1, 2, 4, 8, 16, 32, 48)),
    "full": dict(sift_n=100_000, gist_n=20_000, n_queries=2_000, batch=2_000,
                 n_rep=256, efs=(1, 2, 4, 8, 16, 32, 48)),
}
P = PRESETS[SCALE]


@functools.lru_cache(maxsize=None)
def dataset(name: str):
    if name == "sift":
        return sift_like(n=P["sift_n"], n_queries=P["n_queries"], seed=0)
    return gist_like(n=P["gist_n"], n_queries=max(P["n_queries"] // 4, 64),
                     seed=0)


@functools.lru_cache(maxsize=None)
def engine(name: str, mode: str, search_mode: str = "graph",
           fabric: str = "rdma", b: int = 4):
    ds = dataset(name)
    cfg = EngineConfig(
        mode=mode, search_mode=search_mode, b=b, ef=48,
        n_rep=min(P["n_rep"], ds.data.shape[0] // 16),
        cache_frac=0.10, doorbell=16,
        fabric=RDMA_100G if fabric == "rdma" else TPU_ICI, seed=0)
    t0 = time.perf_counter()
    eng = DHNSWEngine(cfg).build(ds.data)
    eng.build_s = time.perf_counter() - t0
    return eng


def emit(row: dict) -> None:
    """One CSV line: name,us_per_call,extra key=val pairs."""
    name = row.pop("name")
    us = row.pop("us_per_call", "")
    rest = " ".join(f"{k}={v}" for k, v in row.items())
    print(f"{name},{us},{rest}", flush=True)


def batched_queries(ds, batch):
    q = ds.queries
    if len(q) < batch:
        reps = -(-batch // len(q))
        q = np.concatenate([q] * reps)[:batch]
    return q[:batch]
