"""Memory-pool transport sweep: modeled latency vs network parameters.

The point of the ``MemoryPool`` boundary is that the transport is a
swappable, *measurable* component.  This sweep runs the same workload
through ``SimulatedRDMAPool`` across a grid of fabric calibrations —
round-trip time and payload bandwidth scaled around the paper's
ConnectX-6 testbed — and reports, per scheme:

  * counted verbs (round trips, doorbell descriptors, bytes/query);
  * the pool's modeled wire time per query, with its per-verb breakdown
    (span reads vs row reads vs appends);

so the BENCH numbers reflect round trips and wire time under each
fabric, not just event counts.  The quantized tier rides along to show
the byte reduction translating into modeled time on slow fabrics.

Writes ``BENCH_pool.json``.  ``--smoke`` is the CI crash check: tiny
config, asserts nothing about perf.
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.core import DHNSWEngine, EngineConfig
from repro.core.cost_model import RDMA_100G, Fabric
from repro.data.synthetic import sift_like


def fabric_grid(smoke: bool) -> list[Fabric]:
    base = RDMA_100G
    grid = [base]
    rtt_scales = (5.0,) if smoke else (5.0, 25.0)
    bw_scales = (0.25,) if smoke else (0.25, 0.0625)
    for s in rtt_scales:
        grid.append(Fabric(f"rtt-x{s:g}", rtt_s=base.rtt_s * s,
                           bw_Bps=base.bw_Bps, per_op_s=base.per_op_s * s,
                           max_doorbell=base.max_doorbell))
    for s in bw_scales:
        grid.append(Fabric(f"bw-x{s:g}", rtt_s=base.rtt_s,
                           bw_Bps=base.bw_Bps * s, per_op_s=base.per_op_s,
                           max_doorbell=base.max_doorbell))
    return grid


def run_cell(data, queries, *, mode: str, quant: str, fabric: Fabric,
             n_rep: int, n_batches: int) -> dict:
    cfg = EngineConfig(mode=mode, search_mode="scan", b=4, ef=48,
                       n_rep=n_rep, cache_frac=0.25, doorbell=16,
                       fabric=fabric, seed=0, quant=quant, pool="sim_rdma")
    eng = DHNSWEngine(cfg).build(data)
    per = max(len(queries) // n_batches, 1)
    nq = 0
    t0 = time.perf_counter()
    for i in range(n_batches):
        qb = queries[i * per:(i + 1) * per]
        _, _, st = eng.search(qb, k=10)
        nq += len(qb)
    wall = time.perf_counter() - t0
    snap = eng.pool.snapshot()
    tot = snap["totals"]
    return {"mode": mode, "quant": quant, "fabric": fabric.name,
            "rtt_us": fabric.rtt_s * 1e6,
            "bw_GBps": fabric.bw_Bps / 1e9,
            "round_trips_per_q": round(tot["round_trips"] / nq, 3),
            "descriptors_per_q": round(tot["descriptors"] / nq, 3),
            "kb_per_q": round(tot["bytes"] / nq / 1e3, 2),
            "sim_us_per_q": round(snap["sim_total_s"] / nq * 1e6, 3),
            "sim_breakdown_us": {v: round(s * 1e6, 2)
                                 for v, s in snap["sim_s"].items()},
            "wall_s": round(wall, 2)}


def run(*, smoke: bool = False, out: str = "BENCH_pool.json") -> dict:
    if smoke:
        n, n_rep, n_batches = 1500, 12, 2
        modes = ("full",)
        quants = ("none", "int8")
    else:
        n, n_rep, n_batches = 20_000, 64, 4
        modes = ("naive", "no_doorbell", "full")
        quants = ("none", "int8")
    ds = sift_like(n=n, n_queries=256, seed=0)

    rows = []
    print(f"{'fabric':>10s} {'mode':>12s} {'quant':>5s} {'rt/q':>7s} "
          f"{'KB/q':>9s} {'sim us/q':>9s}")
    for fabric in fabric_grid(smoke):
        for mode in modes:
            for quant in quants:
                row = run_cell(ds.data, ds.queries, mode=mode, quant=quant,
                               fabric=fabric, n_rep=n_rep,
                               n_batches=n_batches)
                rows.append(row)
                print(f"{row['fabric']:>10s} {mode:>12s} {quant:>5s} "
                      f"{row['round_trips_per_q']:7.3f} "
                      f"{row['kb_per_q']:9.2f} "
                      f"{row['sim_us_per_q']:9.3f}", flush=True)

    blob = {"bench": "pool", "smoke": smoke, "n": n, "n_rep": n_rep,
            "n_batches": n_batches, "rows": rows}
    with open(out, "w") as f:
        json.dump(blob, f, indent=2)
    print(f"wrote {out} ({len(rows)} rows)")
    return blob


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI config; crash-check only")
    ap.add_argument("--out", default="BENCH_pool.json")
    args = ap.parse_args()
    run(smoke=args.smoke, out=args.out)


if __name__ == "__main__":
    main()
