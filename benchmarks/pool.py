"""Memory-pool transport sweep: modeled latency vs network parameters.

The point of the ``MemoryPool`` boundary is that the transport is a
swappable, *measurable* component.  This sweep runs the same workload
through ``SimulatedRDMAPool`` across a grid of fabric calibrations —
round-trip time and payload bandwidth scaled around the paper's
ConnectX-6 testbed — and reports, per scheme:

  * counted verbs (round trips, doorbell descriptors, bytes/query);
  * the pool's modeled wire time per query, with its per-verb breakdown
    (span reads vs row reads vs appends);

so the BENCH numbers reflect round trips and wire time under each
fabric, not just event counts.  The quantized tier rides along to show
the byte reduction translating into modeled time on slow fabrics.

The ``--shards`` sweep runs a SKEWED (zipf-sampled) workload through
``ShardedPool`` across shard count x placement policy, with the last
shard a deliberate straggler (8x slower fabric): per cell it reports
modeled us/query, per-shard wire bytes and their imbalance, and the
migration count — the frequency-aware policy must beat round-robin
here by moving hot groups off the straggler.

The ``--transport`` sweep runs the same workload through LocalPool,
SimulatedRDMAPool, and a REAL loopback ``RemotePool`` (one forked
``PoolServer`` process per row): next to the ledger-modeled bytes it
reports the *measured* wire payload bytes and frames, and asserts
span-verb parity (measured == modeled) — the model validated against
an actual wire instead of trusted.

The ``--chaos`` sweep is the ROADMAP failover gate: the workload runs
with ``replication=2`` over REAL loopback ``PoolServer`` processes and
one server is killed -9 mid-run.  The gate asserts no
``PoolUnavailableError`` reaches the caller, every batch stays
bit-identical to ``LocalPool``, and reports per-batch latency
percentiles (the kill batch pays re-replication once; nothing may hang
on the dead socket, so p99 stays bounded).

The ``--chaos-latency`` sweep is the straggler-observability gate: a
seeded ``WRInjector`` degrades every WR post on one shard of a 3-shard
``replication=2`` sim-RDMA pool, and the run asserts — on the modeled
clock, deterministically — that the straggler detector flags exactly
that shard, replica-ranked reads route around it (cutting modeled p99
vs a detection-off twin), the tail sampler keeps the slow-batch traces
(``why_kept=latency``), the SLO burn rate spikes above 1 and recovers,
and every batch stays bit-identical to ``LocalPool``.

Writes ``BENCH_pool.json``.  ``--smoke`` is the CI crash check: tiny
config, asserts nothing about perf (the transport parity and chaos
asserts still run — they are correctness properties, not perf bars).
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.core import DHNSWEngine, EngineConfig
from repro.core.cost_model import RDMA_100G, Fabric
from repro.data.synthetic import sift_like
from repro.pool.placement import FrequencyAwarePlacement


def fabric_grid(smoke: bool) -> list[Fabric]:
    base = RDMA_100G
    grid = [base]
    rtt_scales = (5.0,) if smoke else (5.0, 25.0)
    bw_scales = (0.25,) if smoke else (0.25, 0.0625)
    for s in rtt_scales:
        grid.append(Fabric(f"rtt-x{s:g}", rtt_s=base.rtt_s * s,
                           bw_Bps=base.bw_Bps, per_op_s=base.per_op_s * s,
                           max_doorbell=base.max_doorbell))
    for s in bw_scales:
        grid.append(Fabric(f"bw-x{s:g}", rtt_s=base.rtt_s,
                           bw_Bps=base.bw_Bps * s, per_op_s=base.per_op_s,
                           max_doorbell=base.max_doorbell))
    return grid


def run_cell(data, queries, *, mode: str, quant: str, fabric: Fabric,
             n_rep: int, n_batches: int) -> dict:
    cfg = EngineConfig(mode=mode, search_mode="scan", b=4, ef=48,
                       n_rep=n_rep, cache_frac=0.25, doorbell=16,
                       fabric=fabric, seed=0, quant=quant, pool="sim_rdma")
    eng = DHNSWEngine(cfg).build(data)
    per = max(len(queries) // n_batches, 1)
    nq = 0
    t0 = time.perf_counter()
    for i in range(n_batches):
        qb = queries[i * per:(i + 1) * per]
        _, _, st = eng.search(qb, k=10)
        nq += len(qb)
    wall = time.perf_counter() - t0
    snap = eng.pool.snapshot()
    tot = snap["totals"]
    return {"mode": mode, "quant": quant, "fabric": fabric.name,
            # full calibration straight from the pool snapshot, so the
            # row is self-describing (rtt/bw/per_op/max_doorbell)
            "fabric_params": snap["fabric"],
            "rtt_us": fabric.rtt_s * 1e6,
            "bw_GBps": fabric.bw_Bps / 1e9,
            "round_trips_per_q": round(tot["round_trips"] / nq, 3),
            "descriptors_per_q": round(tot["descriptors"] / nq, 3),
            "kb_per_q": round(tot["bytes"] / nq / 1e3, 2),
            "sim_us_per_q": round(snap["sim_total_s"] / nq * 1e6, 3),
            "sim_breakdown_us": {v: round(s * 1e6, 2)
                                 for v, s in snap["sim_s"].items()},
            "wall_s": round(wall, 2)}


def run_transport_cell(data, queries, *, transport: str, n_rep: int,
                       n_batches: int, endpoint=None,
                       bearer: str = "tcp") -> dict:
    """One workload through one transport; modeled ledger numbers next
    to (for remote) the measured wire traffic.  ``bearer`` picks the
    remote QP bearer: ``tcp`` frames WRs to a forked ``PoolServer``,
    ``loopback`` runs the identical verbs path against an in-process
    ``HostRegion`` — same frames, no sockets."""
    cfg = EngineConfig(mode="full", search_mode="scan", b=4, ef=48,
                       n_rep=n_rep, cache_frac=0.25, doorbell=16,
                       fabric=RDMA_100G, seed=0, quant="none",
                       pool=transport, bearer=bearer,
                       endpoints=(endpoint,) if endpoint else None)
    eng = DHNSWEngine(cfg).build(data)
    per = max(len(queries) // n_batches, 1)
    nq = 0
    t0 = time.perf_counter()
    for i in range(n_batches):
        qb = queries[i * per:(i + 1) * per]
        eng.search(qb, k=10)
        nq += len(qb)
    wall = time.perf_counter() - t0
    snap = eng.pool.snapshot()
    tot = snap["totals"]
    row = {"transport": transport,
           "round_trips_per_q": round(tot["round_trips"] / nq, 3),
           "descriptors_per_q": round(tot["descriptors"] / nq, 3),
           "model_kb_per_q": round(tot["bytes"] / nq / 1e3, 2),
           "wall_s": round(wall, 2)}
    if transport == "remote":
        wire = snap["wire"]
        wvm = snap["wire_vs_model"]["read_spans"]
        # the whole point of the row: the ledger's modeled span bytes
        # must equal what actually crossed the bearer (socket payload
        # for tcp, HostRegion frames for loopback)
        assert wvm["measured"] == wvm["modeled"], wvm
        row.update({
            "bearer": snap["bearer"],
            "endpoint": snap.get("endpoint"),
            "wire_kb_per_q": round(
                wire["payload_by_verb"]["read_spans"] / nq / 1e3, 2),
            "wire_frames": wire["frames_tx"],
            "wire_frame_overhead_kb": round(
                (wire["bytes_rx"] + wire["bytes_tx"]
                 - sum(wire["payload_by_verb"].values())) / 1e3, 2),
            "inflight_peak": wire["inflight_peak"],
            "span_wire_vs_model": wvm["ratio"]})
    elif transport == "sim_rdma":
        row["sim_us_per_q"] = round(snap["sim_total_s"] / nq * 1e6, 3)
        row["fabric"] = snap["fabric"]
    return row


def run_transports(*, smoke: bool = False) -> list[dict]:
    """LocalPool vs SimulatedRDMAPool vs a RemotePool over each QP
    bearer — loopback (in-process HostRegion) and tcp (one forked
    server process) — on the same workload."""
    from repro.net import spawn_pool_servers
    n, n_rep, n_batches = (1500, 12, 2) if smoke else (20_000, 64, 4)
    ds = sift_like(n=n, n_queries=128 if smoke else 256, seed=0)
    cells = (("local", "tcp"), ("sim_rdma", "tcp"),
             ("remote", "loopback"), ("remote", "tcp"))
    rows = []
    print(f"{'transport':>15s} {'rt/q':>7s} {'model KB/q':>11s} "
          f"{'wire KB/q':>10s} {'wall s':>7s}")
    with spawn_pool_servers(1) as endpoints:
        for transport, bearer in cells:
            remote_tcp = transport == "remote" and bearer == "tcp"
            row = run_transport_cell(
                ds.data, ds.queries, transport=transport, n_rep=n_rep,
                n_batches=n_batches, bearer=bearer,
                endpoint=endpoints[0] if remote_tcp else None)
            rows.append(row)
            label = (f"{transport}/{bearer}" if transport == "remote"
                     else transport)
            print(f"{label:>15s} {row['round_trips_per_q']:7.3f} "
                  f"{row['model_kb_per_q']:11.2f} "
                  f"{row.get('wire_kb_per_q', float('nan')):10.2f} "
                  f"{row['wall_s']:7.2f}", flush=True)
    return rows


def run_chaos(*, smoke: bool = False) -> dict:
    """Kill -9 one of two replicated loopback pool servers mid-run.

    The same batch stream is driven through a ``replication=2`` remote
    pool and a ``LocalPool`` reference in lockstep (same call index, so
    compute-side caches warm identically).  Halfway through, one
    ``PoolServer`` gets SIGKILL.  Asserts the failover contract — no
    error surfaces, results stay bit-identical — and reports per-batch
    latency percentiles plus the failover counters.
    """
    from repro.net import spawn_pool_servers
    n, n_rep, n_batches = (1500, 12, 8) if smoke else (8_000, 32, 16)
    ds = sift_like(n=n, n_queries=64, seed=0)
    kw = dict(mode="full", search_mode="scan", b=3, ef=32, n_rep=n_rep,
              cache_frac=0.25, doorbell=16, fabric=RDMA_100G, seed=0)
    ref = DHNSWEngine(EngineConfig(pool="local", **kw)).build(ds.data)
    per = max(len(ds.queries) // n_batches, 1)
    kill_at = n_batches // 2
    lat, mismatches = [], 0
    # 3 servers so R=2 does NOT fully replicate: the kill strips one
    # replica from ~2/3 of the groups and forces real re-replication
    with spawn_pool_servers(3, with_procs=True) as (eps, procs):
        eng = DHNSWEngine(EngineConfig(pool="remote",
                                       endpoints=tuple(eps),
                                       replication=2, **kw)).build(ds.data)
        for i in range(n_batches):
            qb = ds.queries[i * per:(i + 1) * per]
            if i == kill_at:
                procs[0].kill()
                procs[0].wait(timeout=10)
            t0 = time.perf_counter()
            d, g, _ = eng.search(qb, k=10)
            lat.append(time.perf_counter() - t0)
            dr, gr, _ = ref.search(qb, k=10)
            if not (np.array_equal(d, dr) and np.array_equal(g, gr)):
                mismatches += 1
        snap = eng.pool.snapshot()
    assert mismatches == 0, \
        f"{mismatches} post-kill batches diverged from LocalPool"
    fo = snap["failover"]
    assert fo["deaths"] == 1 and fo["lost_groups"] == 0, fo
    arr = np.asarray(lat, np.float64) * 1e3
    # bounded p99: every batch completed (no hang on the dead socket);
    # the kill batch pays dead-socket detection + re-replication once
    assert np.all(np.isfinite(arr)) and float(arr.max()) < 60_000.0, arr
    row = {"replication": 2, "n_batches": n_batches,
           "kill_batch": kill_at, "deaths": fo["deaths"],
           "read_retries": fo["read_retries"],
           "rereplicated_groups": fo["rereplicated_groups"],
           "rereplicate_mb": round(fo["rereplicate_bytes"] / 1e6, 3),
           "lost_groups": fo["lost_groups"],
           "bit_identical_to_local": True,
           "p50_ms": round(float(np.percentile(arr, 50)), 3),
           "p99_ms": round(float(np.percentile(arr, 99)), 3),
           "kill_batch_ms": round(float(arr[kill_at]), 3)}
    print(f"chaos: kill -9 at batch {kill_at}/{n_batches}, "
          f"rereplicated {row['rereplicated_groups']} groups "
          f"({row['rereplicate_mb']} MB), p50 {row['p50_ms']} ms, "
          f"p99 {row['p99_ms']} ms, kill batch {row['kill_batch_ms']} ms, "
          f"bit-identical to local", flush=True)
    return row


def run_chaos_latency(*, smoke: bool = False) -> dict:
    """Seeded WR-latency chaos on one shard of a replicated sharded pool.

    A ``WRInjector`` degrades every WR post on shard 1 of a 3-shard
    ``replication=2`` sim-RDMA pool.  Two engines run the same batch
    stream: one with the straggler detector on (``straggler_check_every``),
    one with it off.  The row proves, on the MODELED clock (injection
    lands in the observed histograms, never in the cost model):

      * the detector flags exactly the injected shard and replica-ranked
        reads route around it (``inj.posts`` stops growing);
      * the post-detection modeled p99 is cut vs the detection-off twin;
      * the tail sampler keeps the slow batches (``why_kept=latency``);
      * the SLO burn rate spikes > 1 during injection and recovers;
      * results stay bit-identical to a ``LocalPool`` reference with
        tracing + injection on.

    Everything asserted is a deterministic function of the seeded
    schedule and the counted workload — no wall clock.
    """
    from repro.obs.hist import StragglerDetector
    from repro.obs.slo import SLO, SLOTracker
    from repro.obs.trace import TRACER
    from repro.rdma.inject import WRInjector

    n, n_rep = (1500, 12) if smoke else (8_000, 32)
    warm, injected, post = (8, 6, 6) if smoke else (10, 8, 8)
    n_batches = warm + injected + post
    per = 8
    ds = sift_like(n=n, n_queries=64, seed=0)
    base = dict(mode="full", search_mode="scan", b=3, ef=32, n_rep=n_rep,
                cache_frac=0.25, doorbell=16, fabric=RDMA_100G, seed=0)
    ref = DHNSWEngine(EngineConfig(pool="local", **base)).build(ds.data)
    shard_kw = dict(base, pool="sharded", shard_transport="sim_rdma",
                    n_shards=3, replication=2)
    eng_on = DHNSWEngine(EngineConfig(**shard_kw,
                                      straggler_check_every=1)).build(ds.data)
    eng_off = DHNSWEngine(EngineConfig(**shard_kw)).build(ds.data)
    # small smoke workload: fewer samples per (verb, shard) than the
    # detector's production default before it may judge a shard
    eng_on.pool.straggler = StragglerDetector(min_count=4,
                                              min_excess_s=2e-4)
    inj_on = WRInjector(seed=7, delay_s=2e-3)
    inj_off = WRInjector(seed=7, delay_s=2e-3)

    TRACER.configure(trace_id=71, tail=True, tail_quantile=0.95,
                     tail_window=64)
    slo = SLOTracker(SLO(0.99, 0.0, name="p99<model"), short_window=4,
                     long_window=64)
    dts_on, dts_off, burns = [], [], []
    mismatches = 0
    reroute_batch = -1
    for i in range(n_batches):
        if i == warm:
            # SLO threshold: 2x the worst healthy (warm) modeled batch
            thr = 2.0 * max(dts_on)
            slo.slos["serve"] = SLO(0.99, thr, name="p99<model")
            eng_on.pool.children[1].set_injector(inj_on)
            eng_off.pool.children[1].set_injector(inj_off)
            TRACER.set_phase("injected")
        elif i == warm + injected:
            eng_on.pool.children[1].set_injector(None)
            eng_off.pool.children[1].set_injector(None)
            TRACER.set_phase("post")
        elif i == 0:
            TRACER.set_phase("warm")
        s = i % (len(ds.queries) // per)
        qb = ds.queries[s * per:(s + 1) * per]
        # one root per batch: engine spans become children, and the
        # keep/drop decision runs on the deterministic modeled seconds
        with TRACER.span("bench.batch", tier="bench", batch=i) as sp:
            t_on = eng_on.pool.sim_total_s
            d1, g1, _ = eng_on.search(qb, k=10)
            dt_on = eng_on.pool.sim_total_s - t_on
            t_off = eng_off.pool.sim_total_s
            d2, g2, _ = eng_off.search(qb, k=10)
            dt_off = eng_off.pool.sim_total_s - t_off
            dr, gr, _ = ref.search(qb, k=10)
            sp.set(model_s=dt_on)
        for d, g in ((d1, g1), (d2, g2)):
            if not (np.array_equal(d, dr) and np.array_equal(g, gr)):
                mismatches += 1
        dts_on.append(dt_on)
        dts_off.append(dt_off)
        if i >= warm:
            slo.record("serve", "bench", dt_on)
            burns.append(slo.report()["serve"]["bench"]["burn"])
        if reroute_batch < 0 and not np.any(eng_on.pool._serve == 1):
            reroute_batch = i
    TRACER.set_phase(None)

    assert mismatches == 0, \
        f"{mismatches} chaos batches diverged from LocalPool"
    strag = eng_on.pool.snapshot()["stragglers"]
    assert set(strag["flagged"]) == {"1"}, strag
    assert warm <= reroute_batch < warm + injected, reroute_batch
    assert np.any(eng_off.pool._serve == 1)   # detection off: no reroute
    assert inj_on.posts > 0 and inj_off.posts > inj_on.posts

    # modeled p99 over the post-detection injected window: the rerouted
    # engine no longer pays the injected delay, its twin still does
    win = [b for b in range(warm, warm + injected) if b > reroute_batch]
    assert win, "reroute left no post-detection injected batches"
    p99_on = float(np.percentile(np.asarray(dts_on)[win], 99))
    p99_off = float(np.percentile(np.asarray(dts_off)[win], 99))
    assert p99_on < p99_off, (p99_on, p99_off)

    burn_peak = max(burns)
    burn_final = burns[-1]
    assert burn_peak > 1.0, burns
    assert burn_final < 1.0, burns

    spans = TRACER.snapshot()
    slow = [s for s in spans if s["name"] == "bench.batch"
            and s["attrs"].get("why_kept") == "latency"
            and s["attrs"].get("phase") == "injected"]
    assert slow, "tail sampler kept no injected slow-batch traces"
    health = TRACER.health()
    TRACER.disable()

    row = {"n_shards": 3, "replication": 2, "injected_shard": 1,
           "flagged_shard": 1, "delay_us": 2000,
           "n_batches": n_batches, "warm_batches": warm,
           "mismatches": mismatches, "bit_identical_to_local": True,
           "eng_off_serves_injected_shard": True, "burn_recovered": True,
           "reroute_batch": reroute_batch,
           "checks": strag["checks"],
           "moved_groups": strag["moved_groups"],
           "detector_flags": strag["flagged_now"],
           "injected_posts": inj_on.posts,
           "p99_on_us": round(p99_on * 1e6, 3),
           "p99_off_us": round(p99_off * 1e6, 3),
           "p99_cut_ratio": round(p99_on / p99_off, 4),
           "burn_peak": round(burn_peak, 3),
           "kept_traces": health["kept"],
           "discarded_traces": health["discarded"],
           "why_kept_latency": len(slow),
           "ring_dropped": health["dropped"]}
    print(f"chaos-latency: injected shard 1 ({row['delay_us']} us/post), "
          f"flagged at batch {reroute_batch}, moved "
          f"{row['moved_groups']} groups, p99 {row['p99_off_us']} -> "
          f"{row['p99_on_us']} modeled us (x{row['p99_cut_ratio']}), "
          f"burn peak {row['burn_peak']} -> {round(burn_final, 3)}, "
          f"{row['why_kept_latency']} slow traces kept", flush=True)
    return row


def straggler_fabrics(n_shards: int, slowdown: float = 8.0) -> tuple:
    """n_shards fabrics, the last one ``slowdown``x worse on every term."""
    base = RDMA_100G
    slow = Fabric(f"straggler-x{slowdown:g}", rtt_s=base.rtt_s * slowdown,
                  bw_Bps=base.bw_Bps / slowdown,
                  per_op_s=base.per_op_s * slowdown,
                  max_doorbell=base.max_doorbell)
    return (base,) * (n_shards - 1) + (slow,)


def run_shard_cell(data, queries, *, n_shards: int, placement: str,
                   n_rep: int, n_batches: int, per_batch: int,
                   migrate_every: int) -> dict:
    pol = (FrequencyAwarePlacement(migrate_every=migrate_every,
                                   max_moves=4)
           if placement == "freq" else placement)
    cfg = EngineConfig(mode="full", search_mode="scan", b=3, ef=48,
                       n_rep=n_rep, cache_frac=0.1, doorbell=16,
                       fabric=RDMA_100G, seed=0, pool="sharded",
                       n_shards=n_shards, shard_transport="sim_rdma",
                       shard_fabrics=straggler_fabrics(n_shards),
                       placement=pol)
    eng = DHNSWEngine(cfg).build(data)
    # zipf-skewed closed workload: a few hot queries dominate, so a few
    # hot groups dominate the wire — the regime placement matters in
    rng = np.random.default_rng(0)
    p = 1.0 / np.arange(1, len(queries) + 1)
    p /= p.sum()
    nq = 0
    t0 = time.perf_counter()
    for _ in range(n_batches):
        qb = queries[rng.choice(len(queries), size=per_batch, p=p)]
        eng.search(qb, k=10)
        nq += per_batch
    wall = time.perf_counter() - t0
    snap = eng.pool.snapshot()
    by_shard = [s["totals"]["bytes"] for s in snap["shards"]]
    mean_b = max(sum(by_shard) / len(by_shard), 1.0)
    # 1/N block-compacted staging: each child's device region holds
    # only its owned groups, so the per-shard staged footprint (and its
    # max) is a deterministic function of placement — gate-able
    stg = snap.get("staging", {})
    staged_mb = [round(b / 1e6, 3)
                 for b in stg.get("device_bytes_by_shard", [])]
    return {"n_shards": n_shards, "placement": placement,
            "staged_mb_by_shard": staged_mb,
            "staged_mb_max": max(staged_mb) if staged_mb else 0.0,
            "restaged_blocks": stg.get("restaged_blocks", 0),
            "sim_us_per_q": round(snap["sim_total_s"] / nq * 1e6, 3),
            "round_trips_per_q": round(
                snap["totals"]["round_trips"] / nq, 3),
            "kb_per_q": round(snap["totals"]["bytes"] / nq / 1e3, 2),
            "bytes_by_shard_mb": [round(b / 1e6, 3) for b in by_shard],
            "byte_imbalance": round(max(by_shard) / mean_b, 3),
            "migrations": snap["migration"]["n"],
            "groups_by_shard": snap["groups_by_shard"],
            "wall_s": round(wall, 2)}


def run_shards(*, smoke: bool = False) -> list[dict]:
    """Shard count x placement sweep on the skewed straggler workload."""
    if smoke:
        n, n_rep, n_batches, per_batch, migrate_every = 1500, 12, 12, 32, 32
        counts = (2,)
        placements = ("round_robin", "freq")
    else:
        n, n_rep, n_batches, per_batch, migrate_every = (20_000, 64, 16,
                                                         64, 64)
        counts = (2, 4)
        placements = ("round_robin", "size_balanced", "freq")
    ds = sift_like(n=n, n_queries=64, seed=0)
    rows = []
    print(f"{'shards':>6s} {'placement':>13s} {'sim us/q':>9s} "
          f"{'imb':>6s} {'moves':>5s} {'staged MB':>18s}")
    for n_shards in counts:
        for placement in placements:
            row = run_shard_cell(ds.data, ds.queries, n_shards=n_shards,
                                 placement=placement, n_rep=n_rep,
                                 n_batches=n_batches, per_batch=per_batch,
                                 migrate_every=migrate_every)
            rows.append(row)
            staged = "/".join(f"{x:.1f}" for x in row["staged_mb_by_shard"])
            print(f"{n_shards:6d} {placement:>13s} "
                  f"{row['sim_us_per_q']:9.3f} "
                  f"{row['byte_imbalance']:6.3f} "
                  f"{row['migrations']:5d} {staged:>18s}", flush=True)
    return rows


def _load_blob(out: str, fallback: dict) -> dict:
    """Partial sweeps refresh only their table: keep any previously
    written rows (and their metadata) instead of clobbering them."""
    try:
        with open(out) as f:
            return json.load(f)
    except (OSError, ValueError):
        return fallback


def run(*, smoke: bool = False, out: str = "BENCH_pool.json",
        shards_only: bool = False, transport_only: bool = False,
        chaos_only: bool = False, chaos_latency_only: bool = False) -> dict:
    if smoke:
        n, n_rep, n_batches = 1500, 12, 2
        modes = ("full",)
        quants = ("none", "int8")
    else:
        n, n_rep, n_batches = 20_000, 64, 4
        modes = ("naive", "no_doorbell", "full")
        quants = ("none", "int8")
    if transport_only:
        blob = _load_blob(out, {"bench": "pool", "smoke": smoke,
                                "rows": []})
        blob["transport_rows"] = run_transports(smoke=smoke)
        with open(out, "w") as f:
            json.dump(blob, f, indent=2)
        print(f"wrote {out} ({len(blob['transport_rows'])} "
              f"transport rows)")
        return blob
    if chaos_only:
        blob = _load_blob(out, {"bench": "pool", "smoke": smoke,
                                "rows": []})
        blob["chaos"] = run_chaos(smoke=smoke)
        with open(out, "w") as f:
            json.dump(blob, f, indent=2)
        print(f"wrote {out} (chaos row)")
        return blob
    if chaos_latency_only:
        blob = _load_blob(out, {"bench": "pool", "smoke": smoke,
                                "rows": []})
        blob["chaos_latency"] = run_chaos_latency(smoke=smoke)
        with open(out, "w") as f:
            json.dump(blob, f, indent=2)
        print(f"wrote {out} (chaos-latency row)")
        return blob
    rows = []
    if not shards_only:
        ds = sift_like(n=n, n_queries=256, seed=0)
        print(f"{'fabric':>10s} {'mode':>12s} {'quant':>5s} {'rt/q':>7s} "
              f"{'KB/q':>9s} {'sim us/q':>9s}")
        for fabric in fabric_grid(smoke):
            for mode in modes:
                for quant in quants:
                    row = run_cell(ds.data, ds.queries, mode=mode,
                                   quant=quant, fabric=fabric, n_rep=n_rep,
                                   n_batches=n_batches)
                    rows.append(row)
                    print(f"{row['fabric']:>10s} {mode:>12s} {quant:>5s} "
                          f"{row['round_trips_per_q']:7.3f} "
                          f"{row['kb_per_q']:9.2f} "
                          f"{row['sim_us_per_q']:9.3f}", flush=True)

    shard_rows = run_shards(smoke=smoke)
    if shards_only:
        blob = _load_blob(out, {"bench": "pool", "smoke": smoke,
                                "rows": rows})
        blob["shard_rows"] = shard_rows
    else:
        transport_rows = run_transports(smoke=smoke)
        blob = {"bench": "pool", "smoke": smoke, "n": n, "n_rep": n_rep,
                "n_batches": n_batches, "rows": rows,
                "shard_rows": shard_rows,
                "transport_rows": transport_rows,
                "chaos": run_chaos(smoke=smoke),
                "chaos_latency": run_chaos_latency(smoke=smoke)}
    with open(out, "w") as f:
        json.dump(blob, f, indent=2)
    print(f"wrote {out} ({len(blob['rows'])} + {len(shard_rows)} shard "
          f"+ {len(blob.get('transport_rows', []))} transport rows)")
    return blob


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI config; crash-check only")
    ap.add_argument("--shards", action="store_true",
                    help="run only the shard count x placement sweep")
    ap.add_argument("--transport", action="store_true",
                    help="run only the transport comparison (local vs "
                         "sim_rdma vs loopback remote; spawns a server)")
    ap.add_argument("--chaos", action="store_true",
                    help="run only the failover chaos gate (replication=2 "
                         "over loopback servers, kill -9 one mid-run)")
    ap.add_argument("--chaos-latency", action="store_true",
                    help="run only the straggler chaos gate (seeded WR "
                         "latency injection on one shard; detector + "
                         "reroute + tail-sampler + SLO-burn asserts)")
    ap.add_argument("--out", default="BENCH_pool.json")
    args = ap.parse_args()
    run(smoke=args.smoke, out=args.out, shards_only=args.shards,
        transport_only=args.transport, chaos_only=args.chaos,
        chaos_latency_only=args.chaos_latency)


if __name__ == "__main__":
    main()
