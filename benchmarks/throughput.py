"""Throughput & ablations beyond the paper's tables:

  * QPS vs batch size (batching is the paper's §3.3 lever);
  * cache-capacity ablation (hit-rate and bytes saved vs cache_frac);
  * doorbell-width ablation (§3.2's NIC-scalability tradeoff);
  * Pallas distance+topk kernel vs jnp ref on the scan path.
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import P, batched_queries, dataset, emit
from repro.core import DHNSWEngine, EngineConfig
from repro.core.cost_model import RDMA_100G


def _mk(name, **kw):
    ds = dataset(name)
    cfg = dict(mode="full", search_mode="scan", b=4, ef=48,
               n_rep=min(P["n_rep"], ds.data.shape[0] // 16),
               cache_frac=0.10, doorbell=16, fabric=RDMA_100G, seed=0)
    cfg.update(kw)
    return DHNSWEngine(EngineConfig(**cfg)).build(ds.data), ds


def run() -> list[dict]:
    rows = []
    # ---- QPS vs batch
    eng, ds = _mk("sift")
    for batch in (64, 256, 1024):
        if batch > 4 * len(ds.queries):
            continue
        q = batched_queries(ds, batch)
        eng.search(q, k=10)          # warm
        t0 = time.perf_counter()
        _, _, st = eng.search(q, k=10)
        wall = time.perf_counter() - t0
        total = st["net"]["latency_s"] + st["sub_s"] + st["meta_s"]
        row = dict(name=f"throughput/batch{batch}",
                   us_per_call=round(total / batch * 1e6, 2),
                   qps_model=int(batch / total), qps_wall=int(batch / wall),
                   rtpq=round(st["round_trips_per_query"], 5))
        rows.append(row)
        emit(dict(row))

    # ---- cache-capacity ablation
    for frac in (0.02, 0.10, 0.30):
        eng, ds = _mk("sift", cache_frac=frac)
        q = batched_queries(ds, P["batch"])
        eng.search(q, k=10)
        _, _, st = eng.search(q, k=10)
        row = dict(name=f"cache/frac{frac}", us_per_call="",
                   hits=st["cache_hits"], fetches=st["n_fetches"],
                   bytes=int(st["net"]["bytes"]))
        rows.append(row)
        emit(dict(row))

    # ---- doorbell-width ablation
    for db in (1, 4, 16, 64):
        eng, ds = _mk("sift", doorbell=db)
        q = batched_queries(ds, P["batch"])
        _, _, st = eng.search(q, k=10)
        row = dict(name=f"doorbell/width{db}", us_per_call="",
                   trips=st["net"]["round_trips"],
                   net_us=round(st["net"]["latency_s"] * 1e6, 1))
        rows.append(row)
        emit(dict(row))

    # ---- kernel vs ref on the hot loop
    from repro.kernels.distance_topk.ops import distance_topk
    ds = dataset("sift")
    q = jnp.asarray(ds.queries[:128])
    x = jnp.asarray(ds.data[:4096])
    for use_ref in (True, False):
        distance_topk(q, x, 10, use_ref=use_ref)  # compile
        t0 = time.perf_counter()
        for _ in range(5):
            distance_topk(q, x, 10, use_ref=use_ref)[0].block_until_ready()
        dt = (time.perf_counter() - t0) / 5
        row = dict(name=f"kernel/distance_topk/{'ref' if use_ref else 'pallas-interp'}",
                   us_per_call=round(dt * 1e6, 1),
                   note="interpret-mode-on-CPU; TPU perf from roofline")
        rows.append(row)
        emit(dict(row))
    return rows


def main():
    run()


if __name__ == "__main__":
    main()
