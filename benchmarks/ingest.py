"""Ingestion bench: out-of-core bulk load + crash-recovery counters.

Two deterministic tables, both perf-gated (``benchmarks/perf_gate.py``):

* ``load_rows`` — the streaming ``BulkLoader`` against an in-memory
  build of the same dataset.  Every cell asserts bit-identity (meta +
  region) and reports the builder-memory story: ``peak_builder_mb``
  with a chunk budget of 1/8 of the dataset, the configured chunk
  bytes, and the group-shipping verb count.  A growing peak means the
  loader started holding more than O(chunk) again.
* ``recovery`` — one durable loopback ``PoolServer`` (``--data-dir``)
  ingests appends, gets SIGKILL, restarts from its directory, and a
  client with ``attach="auto"`` verifies the fingerprint handshake:
  recovery must ride WAL replay (``replayed_records``), not a region
  re-upload.  The WAL/checkpoint byte counters are deterministic
  functions of the workload, so the gate pins them.

Writes ``BENCH_ingest.json``.  ``--smoke`` is the CI config: tiny
dataset, same asserts (bit-identity and recovered-not-uploaded are
correctness properties, not perf bars).
"""
from __future__ import annotations

import argparse
import json
import os
import signal
import tempfile
import time

import numpy as np

from repro.core import build_meta, build_store
from repro.core.hnsw import HNSWParams
from repro.data.synthetic import sift_like
from repro.ingest import BulkLoader, chunked_source
from repro.pool import LocalPool


class _ShipCounter:
    """Counts ``refresh_blocks`` verbs the loader would put on the wire."""

    def __init__(self):
        self.calls = 0
        self.blocks = 0

    def refresh_blocks(self, ids) -> None:
        self.calls += 1
        self.blocks += int(np.asarray(ids).size)


def run_load(*, smoke: bool = False) -> list[dict]:
    """Stream-build vs in-memory build: bit-identity + bounded memory."""
    n, n_rep = (1600, 12) if smoke else (20_000, 64)
    ds = sift_like(n=n, n_queries=8, seed=0)
    data = ds.data
    chunk_rows = n // 8
    p = HNSWParams(M=8, M0=16, ef_construction=80)

    meta0 = build_meta(data, n_rep, seed=0)
    store0 = build_store(data, meta0, sub_params=p)

    ship = _ShipCounter()
    t0 = time.perf_counter()
    ld = BulkLoader(n_rep=n_rep, chunk_rows=chunk_rows, seed=0,
                    sub_params=p)
    ld.add_chunks(chunked_source(data, chunk_rows))
    meta, store, rep = ld.finalize(into_pool=ship)
    ld.close()
    wall = time.perf_counter() - t0

    identical = (np.array_equal(store.graph_buf, store0.graph_buf)
                 and np.array_equal(store.vec_buf, store0.vec_buf)
                 and np.array_equal(store.meta_table, store0.meta_table)
                 and np.array_equal(meta.graph.adjacency,
                                    meta0.graph.adjacency))
    assert identical, "streamed region diverged from the in-memory build"
    assert rep.peak_builder_bytes < rep.dataset_bytes / 2, rep
    row = {"rows": rep.rows, "dim": rep.dim, "chunk_rows": chunk_rows,
           "chunks": rep.chunks_total, "chunks_failed": rep.chunks_failed,
           "bit_identical": identical,
           "chunk_mb": round(rep.chunk_bytes / 1e6, 3),
           "dataset_mb": round(rep.dataset_bytes / 1e6, 3),
           "peak_builder_mb": round(rep.peak_builder_bytes / 1e6, 3),
           "verbs_issued": rep.verbs_issued,
           "groups_shipped": rep.groups_shipped,
           "wall_s": round(wall, 2)}
    print(f"load: {rep.rows} rows in {rep.chunks_total} chunks, peak "
          f"builder {row['peak_builder_mb']} MB vs dataset "
          f"{row['dataset_mb']} MB, {rep.groups_shipped} groups shipped, "
          f"bit-identical", flush=True)
    return [row]


def run_recovery(*, smoke: bool = False) -> dict:
    """Kill -9 a durable server mid-ingest; recover from its data-dir."""
    from repro.net import RemotePool, spawn_pool_servers
    n, n_appends = (1500, 12) if smoke else (8_000, 64)
    ds = sift_like(n=n, n_queries=4, seed=0)
    meta = build_meta(ds.data, 8, seed=0, meta_levels=2)

    def mk_store():
        return build_store(ds.data, meta, ov_cap=max(n_appends, 8),
                           sub_params=HNSWParams(M=4, M0=8,
                                                 ef_construction=40))

    mirror = mk_store()         # the uninterrupted-run twin
    twin = LocalPool(mirror)
    with tempfile.TemporaryDirectory(prefix="repro_bench_ingest_") as ddir:
        with spawn_pool_servers(1, data_dirs=[ddir],
                                with_procs=True) as (eps, procs):
            pool = RemotePool(mk_store(), eps[0])
            for i in range(n_appends):
                vec = ds.data[0] + 0.01 * (i + 1)
                pid = i % mirror.spec.n_partitions
                gid = 1_000_000 + i
                assert pool.append(vec, gid, pid, ledger=None) >= 0
                twin.append(vec, gid, pid, ledger=None)
            pre = pool.server_stats()["ingest"]
            os.kill(procs[0].pid, signal.SIGKILL)
            procs[0].wait(timeout=10)

        t0 = time.perf_counter()
        with spawn_pool_servers(1, data_dirs=[ddir]) as eps2:
            pool2 = RemotePool(mirror, eps2[0], attach="auto")
            wall = time.perf_counter() - t0
            assert pool2.attached_via == "recovered", \
                "recovery must come from the WAL, not a region re-upload"
            ing = pool2.server_stats()["ingest"]
            a = pool2.read_spans(np.arange(4), ledger=None)
        b = twin.read_spans(np.arange(4), ledger=None)
        for x, y in zip(a, b):
            assert np.array_equal(np.asarray(x), np.asarray(y)), \
                "recovered region diverged from the uninterrupted twin"

    row = {"n_appends": n_appends, "attached_via": "recovered",
           "wal_records": pre["wal_records"],
           "wal_kb": round(pre["wal_bytes"] / 1e3, 2),
           "replayed_records": ing["replayed_records"],
           "checkpoint_kb": round(ing["checkpoint_bytes"] / 1e3, 2),
           "recover_wall_s": round(wall, 2)}
    print(f"recovery: {row['wal_records']} WAL records "
          f"({row['wal_kb']} KB) -> kill -9 -> replayed "
          f"{row['replayed_records']}, re-attach via fingerprint "
          f"handshake in {row['recover_wall_s']} s, region bit-identical",
          flush=True)
    return row


def run(*, smoke: bool = False, out: str = "BENCH_ingest.json") -> dict:
    blob = {"bench": "ingest", "smoke": smoke,
            "load_rows": run_load(smoke=smoke),
            "recovery": run_recovery(smoke=smoke)}
    with open(out, "w") as f:
        json.dump(blob, f, indent=2)
    print(f"wrote {out}")
    return blob


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI config; asserts still run")
    ap.add_argument("--out", default="BENCH_ingest.json")
    args = ap.parse_args()
    run(smoke=args.smoke, out=args.out)


if __name__ == "__main__":
    main()
