"""Paper-geometry headline run: the closest this box gets to the
paper's SIFT1M/500-partitions/batch-2000 setup.

    PYTHONPATH=src python -m benchmarks.headline_full

100k x 128d clustered vectors, 256 partitions, batch 2000, b=4, ef=48,
RDMA fabric.  Reproduces (see EXPERIMENTS.md §Paper):
    recall@10 ~0.86, rtpq 4.0 -> 0.01, naive/full net ratio ~32x.
Takes a few minutes (three engine builds at 100k vectors).
"""
import time

import numpy as np

from repro.core import DHNSWEngine, EngineConfig, recall_at_k
from repro.core.cost_model import RDMA_100G
from repro.data.synthetic import sift_like


def main():
    ds = sift_like(n=100_000, n_queries=2000, seed=0)
    res = {}
    for mode in ("naive", "no_doorbell", "full"):
        t0 = time.time()
        eng = DHNSWEngine(EngineConfig(
            mode=mode, search_mode="graph", b=4, ef=48, n_rep=256,
            cache_frac=0.10, doorbell=16, fabric=RDMA_100G,
            seed=0)).build(ds.data)
        tb = time.time() - t0
        d, g, st = eng.search(ds.queries, k=10, ef=48)
        rec = recall_at_k(g, ds.gt_ids[:, :10])
        res[mode] = st
        print(f"{mode:12s} build {tb:.0f}s recall@10 {rec:.4f} "
              f"net_us_q {st['net']['latency_s']/2000*1e6:.2f} "
              f"rtpq {st['round_trips_per_query']:.5f} "
              f"bytes_q {st['net']['bytes']/2000:.0f}", flush=True)
    n, f = res["naive"], res["full"]
    print(f"HEADLINE naive/full net ratio @batch2000: "
          f"{n['net']['latency_s']/f['net']['latency_s']:.1f}x "
          f"(trips {n['net']['round_trips']:.0f} vs "
          f"{f['net']['round_trips']:.0f})")


if __name__ == "__main__":
    main()
