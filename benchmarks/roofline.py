"""Roofline analysis from the compiled dry-run artifacts (deliverable g).

Per (arch x shape x mesh) cell, from results/dryrun.jsonl:

  compute    = HLO_FLOPs / (chips * 197e12 bf16 FLOP/s)
  memory     = HLO_bytes / (chips * 819e9 B/s HBM)
  collective = wire_bytes_per_device / 50e9 B/s per ICI link

HLO_FLOPs / bytes come from compiled.cost_analysis(); collective bytes
from parsing the post-SPMD HLO (launch/dryrun.py::parse_collectives,
ring-model per-device wire bytes).  cost_analysis on the CPU backend
reports per-PROGRAM totals of the SPMD module (one device's program), so
flops/bytes are already per-device: divide by per-chip peaks directly.

Also reported: MODEL_FLOPS = 6ND (train) / 2ND (serve), the useful-work
ratio MODEL_FLOPS / (HLO_FLOPs * chips), the dominant term, and the
roofline fraction = model-ideal time / dominant time.
"""
from __future__ import annotations

import json
import os
from dataclasses import dataclass

PEAK_FLOPS = 197e12      # bf16 per chip (v5e-class)
HBM_BW = 819e9           # B/s per chip
ICI_BW = 50e9            # B/s per link

DRYRUN = os.environ.get("DRYRUN_JSONL", "results/dryrun.jsonl")


@dataclass
class Cell:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_dev: float          # per-device HLO flops
    bytes_dev: float          # per-device HLO bytes accessed
    wire_dev: float           # per-device collective wire bytes
    model_flops: float
    n_collectives: int

    @property
    def t_compute(self):
        return self.flops_dev / PEAK_FLOPS

    @property
    def t_memory(self):
        return self.bytes_dev / HBM_BW

    @property
    def t_collective(self):
        return self.wire_dev / ICI_BW

    @property
    def dominant(self):
        ts = {"compute": self.t_compute, "memory": self.t_memory,
              "collective": self.t_collective}
        return max(ts, key=ts.get)

    @property
    def t_dominant(self):
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_ratio(self):
        """MODEL_FLOPS / total compiled flops (catches remat/waste)."""
        total = self.flops_dev * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def roofline_fraction(self):
        """model-ideal compute time / dominant-term time: how close the
        compiled program is to the best this workload could do."""
        ideal = self.model_flops / self.chips / PEAK_FLOPS
        return ideal / self.t_dominant if self.t_dominant else 0.0


HLO_COST = os.environ.get("HLO_COST_JSONL", "results/hlo_cost.jsonl")


def load_cells(path: str = DRYRUN, mesh: str = "single") -> list[Cell]:
    """Prefer the layer-exact costing pass (benchmarks/hlo_cost.py, which
    corrects cost_analysis's scan-body-counted-once undercount); fall
    back to the raw dry-run numbers for cells it hasn't covered."""
    exact = {}
    if os.path.exists(HLO_COST):
        for line in open(HLO_COST):
            r = json.loads(line)
            if r.get("status") == "ok":
                exact[(r["arch"], r["shape"])] = r
    cells = []
    for line in open(path):
        r = json.loads(line)
        if r.get("status") != "ok" or r.get("mesh") != mesh:
            continue
        cost = r.get("cost", {})
        coll = r.get("collectives", {})
        e = exact.get((r["arch"], r["shape"]))
        cells.append(Cell(
            arch=r["arch"], shape=r["shape"], mesh=r["mesh"],
            chips=r.get("n_devices", 256),
            flops_dev=float(e["flops_dev"] if e else cost.get("flops", 0.0)),
            bytes_dev=float(e["bytes_dev"] if e else
                            cost.get("bytes accessed", 0.0)),
            wire_dev=float(e["wire_dev"] if e else
                           coll.get("wire_bytes_per_device", 0.0)),
            model_flops=float(r.get("model_flops", 0.0)),
            n_collectives=int(coll.get("n_collectives", 0))))
    return cells


def run(mesh: str = "single") -> list[dict]:
    cells = load_cells(mesh=mesh)
    rows = []
    for c in sorted(cells, key=lambda c: (c.arch, c.shape)):
        row = dict(
            name=f"roofline/{c.arch}/{c.shape}/{c.mesh}",
            us_per_call=round(c.t_dominant * 1e6, 1),
            t_compute_s=f"{c.t_compute:.3e}",
            t_memory_s=f"{c.t_memory:.3e}",
            t_collective_s=f"{c.t_collective:.3e}",
            dominant=c.dominant,
            useful=round(c.useful_ratio, 3),
            roofline_frac=round(c.roofline_fraction, 3))
        rows.append(row)
    return rows


def main():
    from benchmarks.common import emit
    for mesh in ("single",):
        for row in run(mesh):
            emit(dict(row))
    # summary: worst cells (hillclimb candidates)
    cells = load_cells()
    ranked = sorted(cells, key=lambda c: c.roofline_fraction)
    print("# worst roofline fractions:")
    for c in ranked[:5]:
        print(f"#   {c.arch}/{c.shape}: {c.roofline_fraction:.3f} "
              f"(dominant: {c.dominant})")
    coll = sorted(cells, key=lambda c: -(c.t_collective / max(c.t_dominant, 1e-30)))
    print("# most collective-bound:")
    for c in coll[:5]:
        print(f"#   {c.arch}/{c.shape}: coll/dom = "
              f"{c.t_collective / max(c.t_dominant, 1e-30):.3f}")


if __name__ == "__main__":
    main()
