"""Quantized-tier sweep: recall vs bytes-on-the-wire across tier splits.

For each scheme the staged int8 path is compared against the exact
single-tier engine at the SAME cache byte budget:

  * ``quant=none``  — every miss moves a full-precision span;
  * ``quant=int8``  — stage-1 misses move int8 codes + codebook blocks
                      into a ~3-4x larger quantized tier, stage 2 moves
                      only the candidate rows it re-ranks.

The sweep axes are the tier split (``exact_frac`` — the share of the
byte budget kept as full-precision slots) and the re-rank pool size
(``rerank_m``).  Each cell runs several query batches (so tier reuse,
not just the cold fetch, is measured) and reports recall@10 against the
dataset's exact ground truth next to total fetched/saved bytes.

Also A/Bs the fused int8 Pallas kernel (kernels/quant_topk) against its
pure-jnp oracle on a flat database — match + wall time.

Writes ``BENCH_quant.json``.  ``--smoke`` is the CI crash check: tiny
config, asserts nothing about perf.
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.core import DHNSWEngine, EngineConfig, recall_at_k
from repro.core.cost_model import RDMA_100G
from repro.data.synthetic import sift_like
from repro.obs.trace import TRACER


def run_cell(data, queries, gt, *, quant: str, exact_frac: float,
             rerank_m: int, n_rep: int, n_batches: int, k: int = 10,
             quant_kernel: str = "off", cache_frac: float = 0.25,
             seed: int = 0) -> dict:
    cfg = EngineConfig(mode="full", search_mode="scan", b=6, ef=48,
                       n_rep=n_rep, cache_frac=cache_frac, doorbell=16,
                       fabric=RDMA_100G, seed=seed, quant=quant,
                       exact_frac=exact_frac, rerank_m=rerank_m,
                       quant_kernel=quant_kernel)
    eng = DHNSWEngine(cfg).build(data)
    per = max(len(queries) // n_batches, 1)
    tot_bytes = tot_saved = trips = 0.0
    recs = []
    t0 = time.perf_counter()
    for i in range(n_batches):
        qb = queries[i * per:(i + 1) * per]
        _, g, st = eng.search(qb, k=k)
        tot_bytes += st["net"]["bytes"]
        tot_saved += st["net"]["bytes_saved"]
        trips += st["net"]["round_trips"]
        recs.append(recall_at_k(g, gt[i * per:(i + 1) * per, :k]))
    wall = time.perf_counter() - t0
    row = {"quant": quant, "recall": round(float(np.mean(recs)), 4),
           "mbytes": round(tot_bytes / 1e6, 3),
           "mbytes_saved": round(tot_saved / 1e6, 3),
           "round_trips": trips, "wall_s": round(wall, 2)}
    if quant != "none":
        row.update(exact_frac=exact_frac, rerank_m=rerank_m,
                   quant_slots=eng.tiers.quant.capacity,
                   exact_slots=eng.tiers.exact.capacity)
    if quant_kernel != "off":
        row.update(quant_kernel=quant_kernel,
                   kernel_active=st.get("quant_kernel") == "flat")
    return row


def kernel_ab(n: int = 4096, d: int = 128, k: int = 10,
              seed: int = 0) -> dict:
    """Fused int8 Pallas kernel vs the pure-jnp oracle on a flat DB."""
    import jax
    import jax.numpy as jnp

    from repro.kernels.quant_topk.ops import quant_topk
    from repro.quant.codec import quantize_groups

    rng = np.random.default_rng(seed)
    x = rng.standard_normal((n, d)).astype(np.float32)
    q = rng.standard_normal((64, d)).astype(np.float32)
    codes, scales = quantize_groups(x, 32)
    qj, cj, sj = jnp.asarray(q), jnp.asarray(codes), jnp.asarray(scales)

    out = {}
    for name, use_ref in (("pallas", False), ("ref", True)):
        dd, ii = quant_topk(qj, cj, sj, k, 32, use_ref=use_ref)
        jax.block_until_ready((dd, ii))
        t0 = time.perf_counter()
        dd, ii = quant_topk(qj, cj, sj, k, 32, use_ref=use_ref)
        jax.block_until_ready((dd, ii))
        out[f"{name}_us"] = round((time.perf_counter() - t0) * 1e6, 1)
        out[name] = (np.asarray(dd), np.asarray(ii))
    match = float(np.mean(out["pallas"][1] == out["ref"][1]))
    return {"bench": "quant_topk_kernel", "n": n, "d": d, "k": k,
            "id_match": match, "pallas_us": out["pallas_us"],
            "ref_us": out["ref_us"]}


def run(*, smoke: bool = False, out: str = "BENCH_quant.json",
        seed: int = 0, trace_out: str | None = None) -> dict:
    # --trace records the kernel A/B through repro.obs: every
    # quant_topk call becomes a ``kernel.quant_topk`` span tagged with
    # impl=pallas|ref, so `python -m repro.obs.report` can put a number
    # on the Pallas-vs-oracle gap per call (not just the 1-shot *_us)
    if trace_out:
        TRACER.configure()
        TRACER.set_phase("kernel_ab")
    if smoke:
        n, n_rep, n_batches = 1500, 12, 2
        splits, pools = (0.25,), (0,)
        kab = kernel_ab(n=512, d=64, k=5, seed=seed)
    else:
        n, n_rep, n_batches = 20_000, 64, 4
        splits, pools = (0.0, 0.25, 0.5), (0, 20, 40)
        kab = kernel_ab(seed=seed)
    if trace_out:
        TRACER.set_phase(None)
    ds = sift_like(n=n, n_queries=256, seed=seed)

    rows = [run_cell(ds.data, ds.queries, ds.gt_ids, quant="none",
                     exact_frac=0.25, rerank_m=0, n_rep=n_rep,
                     n_batches=n_batches, seed=seed)]
    base = rows[0]["mbytes"]
    print(f"{'quant':6s} {'split':>5s} {'m':>4s} {'recall':>7s} "
          f"{'MB':>9s} {'saved MB':>9s} {'reduction':>9s}")
    print(f"{'none':6s} {'-':>5s} {'-':>4s} {rows[0]['recall']:7.4f} "
          f"{base:9.2f} {'-':>9s} {'-':>9s}", flush=True)
    for split in splits:
        for m in pools:
            row = run_cell(ds.data, ds.queries, ds.gt_ids, quant="int8",
                           exact_frac=split, rerank_m=m, n_rep=n_rep,
                           n_batches=n_batches, seed=seed)
            row["bytes_reduction"] = round(base / max(row["mbytes"], 1e-9), 2)
            rows.append(row)
            print(f"{'int8':6s} {split:5.2f} {m:4d} {row['recall']:7.4f} "
                  f"{row['mbytes']:9.2f} {row['mbytes_saved']:9.2f} "
                  f"x{row['bytes_reduction']:8.2f}", flush=True)

    # dense-resident flat stage-1 A/B: the quant_topk Pallas kernel over
    # the whole resident int8 database vs the per-pair jnp staged path
    # (cache budget raised so the quant tier holds every partition)
    for qk in ("auto", "ref"):
        row = run_cell(ds.data, ds.queries, ds.gt_ids, quant="int8",
                       exact_frac=0.25, rerank_m=0, n_rep=n_rep,
                       n_batches=n_batches, quant_kernel=qk,
                       cache_frac=0.6, seed=seed)
        row["bytes_reduction"] = round(base / max(row["mbytes"], 1e-9), 2)
        rows.append(row)
        tag = {"auto": "flatk", "ref": "flatr"}[qk]
        print(f"{tag:6s} {0.25:5.2f} {0:4d} {row['recall']:7.4f} "
              f"{row['mbytes']:9.2f} {row['mbytes_saved']:9.2f} "
              f"x{row['bytes_reduction']:8.2f}  "
              f"active={row['kernel_active']}", flush=True)

    print(f"kernel A/B: id_match {kab['id_match']:.3f}  "
          f"pallas {kab['pallas_us']} us vs ref {kab['ref_us']} us")
    if trace_out:
        n_spans = TRACER.save(trace_out)
        TRACER.disable()
        print(f"wrote {trace_out} ({n_spans} spans) — inspect with "
              f"`python -m repro.obs.report {trace_out}`")
    blob = {"bench": "quant", "smoke": smoke, "n": n, "n_rep": n_rep,
            "n_batches": n_batches, "rows": rows, "kernel": kab}
    with open(out, "w") as f:
        json.dump(blob, f, indent=2)
    print(f"wrote {out} ({len(rows)} rows)")
    return blob


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI config; crash-check only")
    ap.add_argument("--out", default="BENCH_quant.json")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace", default=None, metavar="FILE",
                    help="record the kernel A/B (and the sweep) with "
                         "repro.obs; write Chrome-trace JSON to FILE")
    args = ap.parse_args()
    run(smoke=args.smoke, out=args.out, seed=args.seed,
        trace_out=args.trace)


if __name__ == "__main__":
    main()
