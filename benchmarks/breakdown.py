"""Tables 1-2 reproduction: per-query latency breakdown at efSearch=48,
top-1 — network / sub-HNSW / meta-HNSW, + round-trips per query.

Paper reference points (per query):
  SIFT1M:  naive net 90271us, w/o-doorbell 607.5us, d-HNSW 527.6us;
           trips 3.547 / 0.896 / 4.75e-3
  GIST1M:  naive net 422.9ms, w/o-doorbell 2.9ms, d-HNSW 1.3ms
"""
from __future__ import annotations

from benchmarks.common import P, batched_queries, dataset, emit, engine
from repro.core.hnsw import recall_at_k


def run(datasets=("sift", "gist")) -> list[dict]:
    rows = []
    for name in datasets:
        ds = dataset(name)
        queries = batched_queries(ds, P["batch"])
        for mode in ("naive", "no_doorbell", "full"):
            eng = engine(name, mode)
            # steady state: warm once, then measure
            eng.search(queries, k=1, ef=48)
            d, g, st = eng.search(queries, k=1, ef=48)
            B = len(queries)
            row = dict(
                name=f"table/{name}@1/{mode}",
                us_per_call=round(
                    (st["net"]["latency_s"] + st["sub_s"] + st["meta_s"])
                    / B * 1e6, 2),
                net_us_q=round(st["net"]["latency_s"] / B * 1e6, 3),
                sub_us_q=round(st["sub_s"] / B * 1e6, 2),
                meta_us_q=round(st["meta_s"] / B * 1e6, 2),
                rtpq=round(st["round_trips_per_query"], 5),
                bytes_q=int(st["net"]["bytes"] / B),
                recall=round(recall_at_k(
                    g[: min(B, len(ds.queries))],
                    ds.gt_ids[: min(B, len(ds.queries)), :1]), 4))
            rows.append(row)
            emit(dict(row))
    return rows


def main():
    run()


if __name__ == "__main__":
    main()
