"""Benchmark harness entry point — one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [suite ...]

Suites: fig6 (latency-recall), tables (breakdown), throughput, insert,
roofline, serving (offered-load sweep -> BENCH_serving.json), quant
(recall-vs-bytes tier-split sweep -> BENCH_quant.json), pool (modeled
latency vs simulated network parameters -> BENCH_pool.json).
Default: all.  Prints ``name,us_per_call,key=val...`` CSV.
Scale via REPRO_BENCH_SCALE={quick,full} (see benchmarks/common.py).
"""
from __future__ import annotations

import os
import sys
import time
import traceback

SUITES = ["fig6", "tables", "throughput", "insert", "roofline", "serving",
          "quant", "pool"]


def main() -> None:
    want = sys.argv[1:] or SUITES
    print(f"# benchmark run: suites={want}", flush=True)
    failures = []
    for suite in want:
        t0 = time.time()
        print(f"# --- {suite} ---", flush=True)
        try:
            if suite == "fig6":
                from benchmarks.latency_recall import run
                run()
            elif suite == "tables":
                from benchmarks.breakdown import run
                run()
            elif suite == "throughput":
                from benchmarks.throughput import run
                run()
            elif suite == "insert":
                from benchmarks.insert import run
                run()
            elif suite == "roofline":
                from benchmarks.roofline import main as rl
                rl()
            elif suite == "serving":
                from benchmarks.serving import run as sv
                sv(smoke=os.environ.get("REPRO_BENCH_SCALE",
                                        "quick") == "quick")
            elif suite == "quant":
                from benchmarks.quant import run as qr
                qr(smoke=os.environ.get("REPRO_BENCH_SCALE",
                                        "quick") == "quick")
            elif suite == "pool":
                from benchmarks.pool import run as pr
                pr(smoke=os.environ.get("REPRO_BENCH_SCALE",
                                        "quick") == "quick")
            else:
                print(f"# unknown suite {suite}")
                continue
        except Exception:
            failures.append(suite)
            print(f"# SUITE FAILED: {suite}")
            traceback.print_exc()
        print(f"# --- {suite} done in {time.time() - t0:.1f}s ---",
              flush=True)
    if failures:
        sys.exit(f"failed suites: {failures}")


if __name__ == "__main__":
    main()
