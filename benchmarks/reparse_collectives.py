"""Re-parse collective bytes of saved dry-run HLO with the current
parser (the parser gained result-size fallbacks after the first dry-run
pass; the .hlo.gz artifacts are the source of truth)."""
import gzip
import json
import os
import sys

sys.path.insert(0, "src")
from repro.launch.dryrun import parse_collectives  # noqa: E402

HLO_DIR = "results/hlo"
JSONL = "results/dryrun.jsonl"


def main():
    rows = [json.loads(l) for l in open(JSONL)]
    n = 0
    for r in rows:
        f = r.get("hlo_file")
        if not f:
            continue
        path = os.path.join(HLO_DIR, f)
        if not os.path.exists(path):
            continue
        hlo = gzip.open(path, "rt").read()
        r["collectives"] = parse_collectives(hlo)
        n += 1
    with open(JSONL, "w") as out:
        for r in rows:
            out.write(json.dumps(r) + "\n")
    print(f"re-parsed {n}/{len(rows)} cells")


if __name__ == "__main__":
    main()
