"""Dynamic insertion benchmark (paper §3.2's overflow design):

  * per-insert latency (host mirror + device scatter + modeled WRITE);
  * recall immediately after insert (no repack) — overflow vectors must
    be served from the shared region by the very next fetch;
  * repack frequency and cost when the shared region fills.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import P, dataset, emit
from repro.core import DHNSWEngine, EngineConfig
from repro.core.cost_model import RDMA_100G
from repro.core.hnsw import recall_at_k


def run() -> list[dict]:
    rows = []
    ds = dataset("sift")
    n0 = ds.data.shape[0] * 3 // 4
    eng = DHNSWEngine(EngineConfig(
        mode="full", search_mode="scan", b=4, ef=48,
        n_rep=min(P["n_rep"], n0 // 16), cache_frac=0.10,
        doorbell=16, fabric=RDMA_100G, seed=0)).build(ds.data[:n0])

    # baseline recall on held-in queries
    _, g, _ = eng.search(ds.queries, k=10)

    new = ds.data[n0:n0 + 256]
    t0 = time.perf_counter()
    gids = eng.insert(new)
    dt = time.perf_counter() - t0
    row = dict(name="insert/latency",
               us_per_call=round(dt / len(new) * 1e6, 1),
               n=len(new),
               net=eng._last_insert_net["latency_s"])
    rows.append(row)
    emit(dict(row))

    # inserted vectors are immediately searchable
    _, gi, _ = eng.search(new[:64], k=1)
    hit = float(np.mean([gids[i] in gi[i] for i in range(64)]))
    row = dict(name="insert/self-recall@1", us_per_call="", hit=hit)
    rows.append(row)
    emit(dict(row))

    # stress one partition to force repacks
    target = ds.data[5]
    burst = target[None] + 0.0005 * np.random.default_rng(1).standard_normal(
        (eng.store.spec.ov_cap + 8, eng.store.spec.dim)).astype(np.float32)
    t0 = time.perf_counter()
    bg = eng.insert(burst)
    dt = time.perf_counter() - t0
    _, gb, _ = eng.search(burst[:32], k=1)
    hit2 = float(np.mean([bg[i] in gb[i] for i in range(32)]))
    row = dict(name="insert/burst-with-repack",
               us_per_call=round(dt / len(burst) * 1e6, 1),
               self_recall=hit2)
    rows.append(row)
    emit(dict(row))
    return rows


def main():
    run()


if __name__ == "__main__":
    main()
